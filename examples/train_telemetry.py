"""Training telemetry with client-side local aggregation (PR 9).

A training loop pushes per-step metric scalars through ``TrainTelemetry``;
the metric channel is an ``Agg[STRINTMap]`` stream, so the scalars sum
in-network and a monitor reads them back at any time.  Metrics are
latency-insensitive, which makes them the natural target for
``local_accum=N``: the client folds N pushes into ONE switch-bound update
before they even join the scheduler queue — same exact sums, a fraction
of the pipeline traversals.

What this example demonstrates (and self-asserts):

- ``TrainTelemetry(..., local_accum=4)`` threads the option through the
  typed schema; the step loop needs no change at all.
- Reads stay consistent mid-fold: ``read()`` rides the same channel and
  the promote-before-read barrier flushes any open (partial) fold first,
  so a read after 30 pushes sees all 30 — including the 2 sitting in an
  unsealed fold buffer.
- Exactness: fixed-point quantized sums are element-exact vs the plain
  per-call path (fold math is the same integer addition, done earlier).
- The always-on channel stats (``local_folds``/``flushes``/
  ``traffic_reduction``) and, with obs enabled, the
  ``inc_local_folds_total`` counter of traversals saved.

    PYTHONPATH=src python -m examples.train_telemetry
"""
import repro.api as inc
from repro.launch.steps import TrainTelemetry

STEPS = 32
ACCUM = 4


def main():
    inc.obs.enable()
    tel = TrainTelemetry(n_workers=1, local_accum=ACCUM)

    # synthetic step loop: three scalars per step, all exact at the metric
    # channel's 3-digit fixed-point precision, so the read-back sums must
    # match the host-side truth to the last digit
    truth = {"loss": 0.0, "lr": 0.0, "tokens": 0.0}
    for step in range(STEPS):
        scalars = {"loss": round(2.5 - 0.05 * step, 3),
                   "lr": 0.001,
                   "tokens": 4096.0}
        for k, v in scalars.items():
            truth[k] += v
        tel.push(scalars)
        if step == STEPS - 3:
            # mid-run read: 30 pushes issued, the last 2 still folding in
            # an unsealed client buffer — the read barrier flushes them
            mid = tel.read(["tokens"])
            assert mid["tokens"] == 30 * 4096.0, mid

    got = tel.read()
    for k, v in truth.items():
        assert abs(got[k] - round(v, 3)) < 1e-9, (k, got[k], v)

    sched = tel.rt.scheduling_report()["train-metrics"]
    folds, flushes = sched["local_folds"], sched["flushes"]
    assert folds == STEPS, sched          # every push was absorbed by a fold
    assert 0 < flushes < STEPS, sched     # ...and folding actually coalesced
    assert sched["traffic_reduction"] > 1.5, sched

    snap = tel.rt.metrics_snapshot()
    saved = snap["metrics"]["counters"].get(
        'inc_local_folds_total{app="train-metrics"}', 0)
    assert saved == folds - flushes, (saved, folds, flushes)

    print(f"{STEPS} metric pushes -> {flushes} switch updates "
          f"(local_accum={ACCUM}, traffic reduction "
          f"{sched['traffic_reduction']}x, {saved} traversals saved)")
    print(f"sums exact at precision=3: loss={got['loss']} lr={got['lr']} "
          f"tokens={got['tokens']}")
    tel.finish()
    inc.obs.disable()
    inc.obs.reset()
    print("== folded telemetry exact; reads consistent mid-fold")


if __name__ == "__main__":
    main()
