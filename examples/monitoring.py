"""Network monitoring / flow counting (paper Figs. 22-24, KeyValue type).

Probes increment per-flow counters in the INC map at line rate (the
ElasticSketch analogue); a monitor process queries hot flows at any time.
The cache-replacement policy keeps hot flows on the 'switch' and spills
the long tail to the server agent.

The typed schema declares the whole app: ``MonitorCall`` streams a
``STRINTMap`` through Map.addTo (plus a pass-through payload the server
handler sees), ``Query`` is a ``ReadMostly`` RPC — the request carries
the keys, their aggregated counts come back via Map.get.  The service's
``drain=`` option sets the channel's schedule: every 16 queued probes
become one INC-map kernel batch; application code never schedules (or
drains) anything.  The Query future is issued on the same channel, so
FIFO order guarantees it observes every probe issued before it.

This example also demonstrates the observability front door
(docs/OBSERVABILITY.md): ``inc.obs.enable(trace=True)`` turns the
data-plane metrics/tracing on, ``inc.metrics()`` records an
application-level counter next to the built-in ones, ``inc.trace(...)``
wraps the probe loop in a user span, and the run ends with the
per-channel p99 latency from ``rt.metrics_snapshot()``.

    PYTHONPATH=src python -m examples.monitoring
"""
import numpy as np

import repro.api as inc


@inc.service(app="MON-1",
             drain=inc.DrainPolicy(max_batch=16, max_delay=0.05,
                                   eager_window=False))
class Monitor:
    @inc.rpc(request_msg="MonitorRequest")
    def MonitorCall(self, kvs: inc.Agg[inc.STRINTMap],
                    payload: inc.Plain) -> {"payload": inc.Plain}: ...

    @inc.rpc(reply_msg="QueryReply")
    def Query(self, kvs: inc.ReadMostly[inc.STRINTMap]): ...


def main():
    # observability on for the whole run: data-plane metrics + span
    # tracing (every 4th coalesced batch lands on the trace timeline)
    inc.obs.enable(trace=True, trace_stride=4)
    rt = inc.IncRuntime()
    rt.server.register("MonitorCall", lambda req: {"payload": "ack"})
    probe = rt.make_stub(Monitor, n_slots=512)
    probes_sent = inc.metrics().counter("mon_probes_total")

    # synthetic zipf traffic: a few elephant flows, many mice. Probes go
    # through the futures front; the schema's size trigger turns every 16
    # of them into one INC-map kernel batch.
    rng = np.random.RandomState(0)
    truth = {}
    futures = []
    with inc.trace("probe_burst", n=200):
        for _ in range(200):
            flows = rng.zipf(1.4, 64) % 2000
            kvs = {}
            for f in flows:
                key = f"flow-{f}"
                kvs[key] = kvs.get(key, 0) + 1
                truth[key] = truth.get(key, 0) + 1
            probes_sent.inc()
            futures.append(probe.MonitorCall(kvs=kvs, payload="probe"))

    # the monitor reads at any time; the Query rides the same channel
    # queue, so it drains behind all 200 probes (.result() demand-flushes)
    reply = probe.Query(kvs={k: 0 for k in truth}).result()
    assert all(f.result()["payload"] == "ack" for f in futures)
    got = {k: int(v) for k, v in reply["kvs"].items()}
    assert got == truth
    hot = sorted(got.items(), key=lambda kv: -kv[1])[:5]
    srv = probe.agents["MonitorCall"].server
    sched = rt.scheduling_report()["MON-1"]
    print("hot flows:", hot)
    print(f"flows tracked: {len(truth)}; switch slots: {srv.capacity}; "
          f"cache hit ratio: {srv.cache_hit_ratio:.3f}")
    print(f"auto-drain: {sched['drained_calls']} calls in "
          f"{sched['drained_batches']} batches (triggers {sched['drains']}), "
          f"mean batch {sched['mean_drained_batch']}")

    # the obs exports: per-channel latency quantiles + the app counter
    snap = rt.metrics_snapshot()
    mon = snap["channels"]["MON-1"]
    probes = snap["metrics"]["counters"]["mon_probes_total"]
    print(f"obs: {probes} probes; submit->resolve "
          f"p50={mon.get('latency_p50_us', 0.0)}us "
          f"p99={mon.get('latency_p99_us', 0.0)}us; "
          f"CHR={snap['switch']['apps']['MON-1']['cache_hit_ratio']:.3f}; "
          f"{len(inc.obs.tracer())} trace events recorded")
    print("== every counter exact (switch + host-spill fallback)")
    rt.close()
    inc.obs.disable()
    inc.obs.reset()


if __name__ == "__main__":
    main()
