"""Network monitoring / flow counting (paper Figs. 22-24, KeyValue type).

Probes increment per-flow counters in the INC map at line rate (the
ElasticSketch analogue); a monitor process queries hot flows at any time.
The cache-replacement policy keeps hot flows on the 'switch' and spills
the long tail to the server agent.

The typed schema declares the whole app: ``MonitorCall`` streams a
``STRINTMap`` through Map.addTo (plus a pass-through payload the server
handler sees), ``Query`` is a ``ReadMostly`` RPC — the request carries
the keys, their aggregated counts come back via Map.get.  The service's
``drain=`` option sets the channel's schedule: every 16 queued probes
become one INC-map kernel batch; application code never schedules (or
drains) anything.  The Query future is issued on the same channel, so
FIFO order guarantees it observes every probe issued before it.

    PYTHONPATH=src python -m examples.monitoring
"""
import numpy as np

import repro.api as inc


@inc.service(app="MON-1",
             drain=inc.DrainPolicy(max_batch=16, max_delay=0.05,
                                   eager_window=False))
class Monitor:
    @inc.rpc(request_msg="MonitorRequest")
    def MonitorCall(self, kvs: inc.Agg[inc.STRINTMap],
                    payload: inc.Plain) -> {"payload": inc.Plain}: ...

    @inc.rpc(reply_msg="QueryReply")
    def Query(self, kvs: inc.ReadMostly[inc.STRINTMap]): ...


def main():
    rt = inc.IncRuntime()
    rt.server.register("MonitorCall", lambda req: {"payload": "ack"})
    probe = rt.make_stub(Monitor, n_slots=512)

    # synthetic zipf traffic: a few elephant flows, many mice. Probes go
    # through the futures front; the schema's size trigger turns every 16
    # of them into one INC-map kernel batch.
    rng = np.random.RandomState(0)
    truth = {}
    futures = []
    for _ in range(200):
        flows = rng.zipf(1.4, 64) % 2000
        kvs = {}
        for f in flows:
            key = f"flow-{f}"
            kvs[key] = kvs.get(key, 0) + 1
            truth[key] = truth.get(key, 0) + 1
        futures.append(probe.MonitorCall(kvs=kvs, payload="probe"))

    # the monitor reads at any time; the Query rides the same channel
    # queue, so it drains behind all 200 probes (.result() demand-flushes)
    reply = probe.Query(kvs={k: 0 for k in truth}).result()
    assert all(f.result()["payload"] == "ack" for f in futures)
    got = {k: int(v) for k, v in reply["kvs"].items()}
    assert got == truth
    hot = sorted(got.items(), key=lambda kv: -kv[1])[:5]
    srv = probe.agents["MonitorCall"].server
    sched = rt.scheduling_report()["MON-1"]
    print("hot flows:", hot)
    print(f"flows tracked: {len(truth)}; switch slots: {srv.capacity}; "
          f"cache hit ratio: {srv.cache_hit_ratio:.3f}")
    print(f"auto-drain: {sched['drained_calls']} calls in "
          f"{sched['drained_batches']} batches (triggers {sched['drains']}), "
          f"mean batch {sched['mean_drained_batch']}")
    print("== every counter exact (switch + host-spill fallback)")
    rt.close()


if __name__ == "__main__":
    main()
