"""Network monitoring / flow counting (paper Figs. 22-24, KeyValue type).

Probes increment per-flow counters in the INC map at line rate (the
ElasticSketch analogue); a monitor process queries hot flows at any time.
The cache-replacement policy keeps hot flows on the 'switch' and spills
the long tail to the server agent.

Probes are issued through the async front: each ``call_async`` returns an
IncFuture immediately and the runtime's size trigger (16) coalesces probes
into one INC-map kernel batch per drain — application code never schedules
(or drains) anything. The Query is a plain synchronous call: the runtime
drains queued probes first, so the read observes every probe issued
before it.

    PYTHONPATH=src python -m examples.monitoring
"""
import numpy as np

from repro.core.netfilter import NetFilter
from repro.core.rpc import Field, Service
from repro.core.runtime import DrainPolicy, IncRuntime


def build_service() -> Service:
    svc = Service("Monitor")
    svc.rpc("MonitorCall", [Field("kvs", "STRINTMap"), Field("payload")],
            [Field("payload")],
            NetFilter.from_dict({"AppName": "MON-1", "Precision": 0,
                                 "addTo": "MonitorRequest.kvs"}))
    svc.rpc("Query", [Field("message")], [Field("kvs", "STRINTMap")],
            NetFilter.from_dict({"AppName": "MON-1", "Precision": 0,
                                 "get": "QueryReply.kvs"}))
    return svc


def main():
    svc = build_service()
    rt = IncRuntime(policy=DrainPolicy(max_batch=16, max_delay=0.05,
                                       eager_window=False))
    rt.server.register("MonitorCall", lambda req: {"payload": "ack"})
    probe = rt.make_stub(svc, n_slots=512)

    # synthetic zipf traffic: a few elephant flows, many mice. Probes go
    # through the futures front; the size trigger turns every 16 of them
    # into one INC-map kernel batch.
    rng = np.random.RandomState(0)
    truth = {}
    futures = []
    for _ in range(200):
        flows = rng.zipf(1.4, 64) % 2000
        kvs = {}
        for f in flows:
            key = f"flow-{f}"
            kvs[key] = kvs.get(key, 0) + 1
            truth[key] = truth.get(key, 0) + 1
        futures.append(probe.call_async(
            "MonitorCall", {"kvs": kvs, "payload": "probe"}))

    # the monitor reads at any time; the inline Query drains queued probes
    # first, so it observes all 200 probes
    reply = probe.call("Query", {"kvs": {k: 0 for k in truth}})
    assert all(f.result()["payload"] == "ack" for f in futures)
    got = {k: int(v) for k, v in reply["kvs"].items()}
    assert got == truth
    hot = sorted(got.items(), key=lambda kv: -kv[1])[:5]
    srv = probe.agents["MonitorCall"].server
    sched = rt.scheduling_report()["MON-1"]
    print("hot flows:", hot)
    print(f"flows tracked: {len(truth)}; switch slots: {srv.capacity}; "
          f"cache hit ratio: {srv.cache_hit_ratio:.3f}")
    print(f"auto-drain: {sched['drained_calls']} probes in "
          f"{sched['drained_batches']} batches (triggers {sched['drains']}), "
          f"mean batch {sched['mean_drained_batch']}")
    print("== every counter exact (switch + host-spill fallback)")
    rt.close()


if __name__ == "__main__":
    main()
