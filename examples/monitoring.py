"""Network monitoring / flow counting (paper Figs. 22-24, KeyValue type).

Probes increment per-flow counters in the INC map at line rate (the
ElasticSketch analogue); a monitor process queries hot flows at any time.
The cache-replacement policy keeps hot flows on the 'switch' and spills
the long tail to the server agent.

    PYTHONPATH=src python -m examples.monitoring
"""
import numpy as np

from repro.core.netfilter import NetFilter
from repro.core.rpc import Field, NetRPC, Service


def build_service() -> Service:
    svc = Service("Monitor")
    svc.rpc("MonitorCall", [Field("kvs", "STRINTMap"), Field("payload")],
            [Field("payload")],
            NetFilter.from_dict({"AppName": "MON-1", "Precision": 0,
                                 "addTo": "MonitorRequest.kvs"}))
    svc.rpc("Query", [Field("message")], [Field("kvs", "STRINTMap")],
            NetFilter.from_dict({"AppName": "MON-1", "Precision": 0,
                                 "get": "QueryReply.kvs"}))
    return svc


def main():
    svc = build_service()
    rt = NetRPC()
    rt.server.register("MonitorCall", lambda req: {"payload": "ack"})
    probe = rt.make_stub(svc, n_slots=512)

    # synthetic zipf traffic: a few elephant flows, many mice. Probes are
    # micro-batched 16 at a time — one INC-map kernel batch per flush
    # instead of one per probe.
    rng = np.random.RandomState(0)
    truth = {}
    probes = []
    for _ in range(200):
        flows = rng.zipf(1.4, 64) % 2000
        kvs = {}
        for f in flows:
            key = f"flow-{f}"
            kvs[key] = kvs.get(key, 0) + 1
            truth[key] = truth.get(key, 0) + 1
        probes.append({"kvs": kvs, "payload": "probe"})
    for i in range(0, len(probes), 16):
        replies = probe.call_batch("MonitorCall", probes[i:i + 16])
        assert all(r["payload"] == "ack" for r in replies)

    reply = probe.call("Query", {"kvs": {k: 0 for k in truth}})
    got = {k: int(v) for k, v in reply["kvs"].items()}
    assert got == truth
    hot = sorted(got.items(), key=lambda kv: -kv[1])[:5]
    srv = probe.agents["MonitorCall"].server
    print("hot flows:", hot)
    print(f"flows tracked: {len(truth)}; switch slots: {srv.capacity}; "
          f"cache hit ratio: {srv.cache_hit_ratio:.3f}")
    print("== every counter exact (switch + host-spill fallback)")


if __name__ == "__main__":
    main()
