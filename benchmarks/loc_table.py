"""Table 4 analogue: lines of user-written code per INC application.

NetRPC's claim: INC apps in ~5% of the LoC of hand-built INC systems.
We count our examples' actual LoC (application code + NetFilter lines,
excluding blanks/comments) against the paper's prior-art numbers.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

EXAMPLES = Path(__file__).resolve().parents[1] / "examples"

# (endhost LoC, switch LoC) from paper Table 4
PRIOR_ART = {
    "SyncAggr": (3394, 5329),
    "AsyncAggr": (3278, 4258),
    "KeyValue": (898, 2360),
    "Agreement": (5441, 931),
}
OUR_FILES = {
    "SyncAggr": "train_mini.py",
    "AsyncAggr": "mapreduce.py",
    "KeyValue": "monitoring.py",
    "Agreement": "paxos.py",
}


def count_loc(path: Path) -> int:
    if not path.exists():
        return 0
    n = 0
    for ln in path.read_text().splitlines():
        s = ln.strip()
        if s and not s.startswith("#") and s != '"""' and not s.startswith(
                '"""'):
            n += 1
    return n


_SCHEMA_RE = re.compile(
    r"@?inc\.(service|rpc|Agg|Get|ReadMostly|CntFwd|DrainPolicy|Plain|"
    r"FPArray|IntArray|STRINTMap|Integer)\b")


def count_netfilter_loc(path: Path) -> int:
    """INC declaration lines inside an example (the 'switch code'): lines
    of the typed schema vocabulary (@inc.service/@inc.rpc decorators and
    Agg/Get/ReadMostly/CntFwd annotations), which compile into the
    NetFilter the legacy JSON blob used to spell out; legacy
    NetFilter.from_dict blocks still count for unported files."""
    if not path.exists():
        return 0
    txt = path.read_text()
    m = re.findall(r"NetFilter\.from_dict\((\{.*?\})\)", txt, re.S)
    legacy = sum(t.count("\n") + 1 for t in m)
    typed = sum(1 for ln in txt.splitlines() if _SCHEMA_RE.search(ln))
    return legacy + typed


def run():
    rows = []
    for app, fname in OUR_FILES.items():
        ours = count_loc(EXAMPLES / fname)
        nf = count_netfilter_loc(EXAMPLES / fname)
        pe, ps = PRIOR_ART[app]
        reduction = 1 - (ours + nf) / (pe + ps)
        rows.append((f"loc/{app}/ours_endhost", 0, ours))
        rows.append((f"loc/{app}/ours_netfilter", 0, nf))
        rows.append((f"loc/{app}/prior_endhost", 0, pe))
        rows.append((f"loc/{app}/prior_switch", 0, ps))
        rows.append((f"loc/{app}/reduction_pct", 0,
                     round(100 * reduction, 1)))
    return rows
