"""GPV wire-path sweep: tensor marshalling cost, dict path vs array path.

ISSUE 4's question: how much of a tensor-channel call was per-element
Python marshalling?  Both legs run the SAME pipeline, switch simulation,
and vectorized INC map — the only difference is ``set_gpv``: the baseline
leg shreds every tensor into a ``{index: value}`` dict on the way in and
out (the pre-GPV wire format), the GPV leg carries it as contiguous
ndarrays end-to-end (TensorSegment).  Each sweep point reports calls/sec
and elements/sec marshalled; the 64k row self-reports the ISSUE acceptance
gate (GPV >= 5x dict, same session, same config).

Every repeat replays an identical gradient stream (SyncAgtr-style
Update: Agg[FPArray] + Get reply + clear="copy") on a fresh runtime with
enough switch slots to map the whole payload; the first (grant-storm)
call is warmup, timed calls hit the steady mapped state. A correctness
probe asserts both legs return element-identical aggregates before any
timing is trusted.

    PYTHONPATH=src python -m benchmarks.wire_path [--smoke] [--csv]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np

import repro.api as inc
from repro.core import rpc as rpc_mod

SIZES = (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18)
GATE_N = 1 << 16        # the acceptance-row payload size
GATE_X = 5.0            # ISSUE 4: GPV >= 5x dict calls/sec at 64k


@inc.service(app="WIRE-1")
class Gradient:
    @inc.rpc(request_msg="NewGrad", reply_msg="AgtrGrad")
    def Update(self, tensor: inc.Agg[inc.FPArray](precision=6,
                                                  clear="copy")
               ) -> {"tensor": inc.Get[inc.FPArray]}: ...

    # write-only accumulate (no reply-path clear), so Fetch below has
    # stable map state to read
    @inc.rpc(request_msg="Accum")
    def Accum(self, tensor: inc.Agg[inc.FPArray](precision=6)): ...

    # pure-query leg (ISSUE 5 satellite): an array-shaped ReadMostly
    # request rides the TensorSegment path — element i reads dense
    # address i — instead of being shredded into a per-element dict
    @inc.rpc(request_msg="FetchReq", reply_msg="FetchReply")
    def Fetch(self, tensor: inc.ReadMostly[inc.FPArray](precision=6)): ...


def _fresh(n: int):
    rt = inc.NetRPC()
    return rt.make_stub(Gradient, n_slots=n)


def _probe(n: int = 256) -> None:
    """Both legs must agree element-exactly — updates AND pure-query
    reads — before timings mean anything."""
    g = np.random.RandomState(0).randn(n).astype(np.float32)
    out = {}
    for gpv in (True, False):
        prev = rpc_mod.set_gpv(gpv)
        try:
            stub = _fresh(n)
            stub.Update(tensor=g).result()
            r = stub.Update(tensor=g).result()["tensor"]
            # Update cleared the map (clear="copy"); accumulate twice
            # without clearing, then read back through the pure query
            stub.Accum(tensor=g).result()
            stub.Accum(tensor=g).result()
            q = stub.Fetch(tensor=np.zeros(n, np.float32)).result()["tensor"]
            out[gpv] = ([r[i] for i in range(n)], [q[i] for i in range(n)])
        finally:
            rpc_mod.set_gpv(prev)
    assert out[True][0] == out[False][0], "GPV leg diverged from dict leg"
    assert out[True][1] == out[False][1], \
        "GPV pure-query read diverged from dict leg"


def _timed_leg(gpv: bool, n: int, iters: int, repeats: int,
               setup, call) -> float:
    """Fastest mean seconds/call of ``call(stub)`` over ``repeats`` timed
    replays on fresh stubs; ``setup(stub)`` runs off-clock per replay
    (grant-storm warmup / map population). One harness for the update and
    read legs, so both always measure under identical conditions
    (gc pinned, min-of-N, same set_gpv bracketing)."""
    import gc
    best = None
    prev = rpc_mod.set_gpv(gpv)
    try:
        for _ in range(repeats):
            stub = _fresh(n)
            setup(stub)
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                for _ in range(iters):
                    call(stub)
                dt = (time.perf_counter() - t0) / iters
            finally:
                gc.enable()
            best = dt if best is None else min(best, dt)
    finally:
        rpc_mod.set_gpv(prev)
    return best


def _time_leg(gpv: bool, n: int, iters: int, repeats: int) -> float:
    """Update (addTo + Get + clear) leg."""
    g = np.random.RandomState(1).randn(n).astype(np.float32)
    return _timed_leg(gpv, n, iters, repeats,
                      setup=lambda stub: stub.Update(tensor=g).result(),
                      call=lambda stub: stub.Update(tensor=g).result())


def _time_read_leg(gpv: bool, n: int, iters: int, repeats: int) -> float:
    """Pure-query Fetch leg (map populated once via Accum, stable across
    the timed reads)."""
    g = np.random.RandomState(2).randn(n).astype(np.float32)
    probe = np.zeros(n, np.float32)

    def setup(stub):
        stub.Accum(tensor=g).result()           # grant storm + population
        stub.Fetch(tensor=probe).result()       # path warmup
    return _timed_leg(gpv, n, iters, repeats, setup=setup,
                      call=lambda stub: stub.Fetch(tensor=probe).result())


def run(sizes=SIZES, repeats: int = 3) -> tuple[list, dict]:
    _probe()
    rows = []
    gate = None
    for n in sizes:
        iters = max(2, min(12, (1 << 19) // n))
        # interleave legs per repeat so box jitter hits both alike
        t_dict = t_gpv = None
        for _ in range(repeats):
            d = _time_leg(False, n, iters, 1)
            a = _time_leg(True, n, iters, 1)
            t_dict = d if t_dict is None else min(t_dict, d)
            t_gpv = a if t_gpv is None else min(t_gpv, a)
        ratio = t_dict / t_gpv
        if n == GATE_N:
            gate = ratio
        for leg, dt in (("dict", t_dict), ("gpv", t_gpv)):
            rows.append((f"t_wire/{leg}/n{n}", round(dt * 1e6, 1),
                         f"calls_per_sec={1.0 / dt:.1f}"
                         f" elems_per_sec={n / dt:.0f}"))
        rows.append((f"t_wire/speedup/n{n}", 0, f"gpv_vs_dict={ratio:.2f}x"))
    # pure-query reads (one representative size): the ReadMostly array
    # request riding the TensorSegment path vs the {i: v} dict reference
    read_n = GATE_N if GATE_N in sizes else max(sizes)
    read_iters = max(2, min(12, (1 << 19) // read_n))
    rd = rr = None
    for _ in range(repeats):
        d = _time_read_leg(False, read_n, read_iters, 1)
        a = _time_read_leg(True, read_n, read_iters, 1)
        rd = d if rd is None else min(rd, d)
        rr = a if rr is None else min(rr, a)
    read_ratio = rd / rr
    for leg, dt in (("dict", rd), ("gpv", rr)):
        rows.append((f"t_wire/read_{leg}/n{read_n}", round(dt * 1e6, 1),
                     f"calls_per_sec={1.0 / dt:.1f}"
                     f" elems_per_sec={read_n / dt:.0f}"))
    rows.append((f"t_wire/read_speedup/n{read_n}", 0,
                 f"gpv_vs_dict={read_ratio:.2f}x"))
    acceptance = {"read_speedup": round(read_ratio, 2),
                  "read_n": read_n}
    if gate is not None:
        verdict = "PASS" if gate >= GATE_X else "FAIL"
        rows.append(("t_wire/acceptance", 0,
                     f"gpv_vs_dict@{GATE_N}={gate:.2f}x"
                     f" (need >= {GATE_X:.0f}x: {verdict})"))
        acceptance.update({"gpv_vs_dict": round(gate, 2),
                           "target": GATE_X, "verdict": verdict})
    return rows, acceptance


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    ap.add_argument("--csv", action="store_true",
                    help="append the rows to benchmarks/results.csv")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    sizes = (1 << 10, 1 << 12) if args.smoke else SIZES
    repeats = 1 if args.smoke else args.repeats
    rows, acceptance = run(sizes, repeats=repeats)
    lines = [",".join(str(x) for x in row) for row in rows]
    for ln in lines:
        print(ln)
    from benchmarks._util import write_bench_json
    # smoke runs export under a separate (gitignored) name so CI never
    # overwrites the committed full-run trajectory with tiny-n noise
    write_bench_json("smoke_wire_path" if args.smoke else "wire_path",
                     {"sizes": list(sizes), "repeats": repeats,
                      "smoke": args.smoke},
                     rows, acceptance)
    if args.csv:
        from pathlib import Path
        out = Path(__file__).resolve().parent / "results.csv"
        with out.open("a") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
