"""GPV wire-path sweep: tensor marshalling cost, dict path vs array path.

ISSUE 4's question: how much of a tensor-channel call was per-element
Python marshalling?  Both legs run the SAME pipeline, switch simulation,
and vectorized INC map — the only difference is ``set_gpv``: the baseline
leg shreds every tensor into a ``{index: value}`` dict on the way in and
out (the pre-GPV wire format), the GPV leg carries it as contiguous
ndarrays end-to-end (TensorSegment).  Each sweep point reports calls/sec
and elements/sec marshalled; the 64k row self-reports the ISSUE acceptance
gate (GPV >= 5x dict, same session, same config).

Every repeat replays an identical gradient stream (SyncAgtr-style
Update: Agg[FPArray] + Get reply + clear="copy") on a fresh runtime with
enough switch slots to map the whole payload; the first (grant-storm)
call is warmup, timed calls hit the steady mapped state. A correctness
probe asserts both legs return element-identical aggregates before any
timing is trusted.

    PYTHONPATH=src python -m benchmarks.wire_path [--smoke] [--csv]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np

import repro.api as inc
from repro.core import rpc as rpc_mod

SIZES = (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18)
GATE_N = 1 << 16        # the acceptance-row payload size
GATE_X = 5.0            # ISSUE 4: GPV >= 5x dict calls/sec at 64k


@inc.service(app="WIRE-1")
class Gradient:
    @inc.rpc(request_msg="NewGrad", reply_msg="AgtrGrad")
    def Update(self, tensor: inc.Agg[inc.FPArray](precision=6,
                                                  clear="copy")
               ) -> {"tensor": inc.Get[inc.FPArray]}: ...


def _fresh(n: int):
    rt = inc.NetRPC()
    return rt.make_stub(Gradient, n_slots=n)


def _probe(n: int = 256) -> None:
    """Both legs must agree element-exactly before timings mean anything."""
    g = np.random.RandomState(0).randn(n).astype(np.float32)
    out = {}
    for gpv in (True, False):
        prev = rpc_mod.set_gpv(gpv)
        try:
            stub = _fresh(n)
            stub.Update(tensor=g).result()
            r = stub.Update(tensor=g).result()["tensor"]
            out[gpv] = [r[i] for i in range(n)]
        finally:
            rpc_mod.set_gpv(prev)
    assert out[True] == out[False], "GPV leg diverged from dict leg"


def _time_leg(gpv: bool, n: int, iters: int, repeats: int) -> float:
    """Fastest mean seconds/call over ``repeats`` timed replays."""
    import gc
    g = np.random.RandomState(1).randn(n).astype(np.float32)
    best = None
    prev = rpc_mod.set_gpv(gpv)
    try:
        for _ in range(repeats):
            stub = _fresh(n)
            stub.Update(tensor=g).result()      # grant-storm warmup
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                for _ in range(iters):
                    stub.Update(tensor=g).result()
                dt = (time.perf_counter() - t0) / iters
            finally:
                gc.enable()
            best = dt if best is None else min(best, dt)
    finally:
        rpc_mod.set_gpv(prev)
    return best


def run(sizes=SIZES, repeats: int = 3) -> list:
    _probe()
    rows = []
    gate = None
    for n in sizes:
        iters = max(2, min(12, (1 << 19) // n))
        # interleave legs per repeat so box jitter hits both alike
        t_dict = t_gpv = None
        for _ in range(repeats):
            d = _time_leg(False, n, iters, 1)
            a = _time_leg(True, n, iters, 1)
            t_dict = d if t_dict is None else min(t_dict, d)
            t_gpv = a if t_gpv is None else min(t_gpv, a)
        ratio = t_dict / t_gpv
        if n == GATE_N:
            gate = ratio
        for leg, dt in (("dict", t_dict), ("gpv", t_gpv)):
            rows.append((f"t_wire/{leg}/n{n}", round(dt * 1e6, 1),
                         f"calls_per_sec={1.0 / dt:.1f}"
                         f" elems_per_sec={n / dt:.0f}"))
        rows.append((f"t_wire/speedup/n{n}", 0, f"gpv_vs_dict={ratio:.2f}x"))
    if gate is not None:
        rows.append(("t_wire/acceptance", 0,
                     f"gpv_vs_dict@{GATE_N}={gate:.2f}x"
                     f" (need >= {GATE_X:.0f}x:"
                     f" {'PASS' if gate >= GATE_X else 'FAIL'})"))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    ap.add_argument("--csv", action="store_true",
                    help="append the rows to benchmarks/results.csv")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    sizes = (1 << 10, 1 << 12) if args.smoke else SIZES
    rows = run(sizes, repeats=1 if args.smoke else args.repeats)
    lines = [",".join(str(x) for x in row) for row in rows]
    for ln in lines:
        print(ln)
    if args.csv:
        from pathlib import Path
        out = Path(__file__).resolve().parent / "results.csv"
        with out.open("a") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
