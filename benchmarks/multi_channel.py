"""Sharded-plane sweep: M independent channels under offered load,
``IncRuntime(workers=N)`` for N in {1, 2, 4}.

ISSUE 5's question: does the worker pool + per-channel plane locking
actually let independent channels drain in parallel, and does the
weighted-fair loop (strict priority tiers, DRR within a tier) keep every
tenant progressing under saturation?

Topology: one strict-priority latency channel (``priority=1``) plus four
bulk channels at ``priority=0`` with DRR weights 8/4/2/1 — five
independent GAIDs sharing one runtime and one host server. The bulk
channels get open-loop submitter threads (admission backpressure is the
only throttle); the latency channel is *paced* at a fixed modest rate —
a saturated strict-priority tier would correctly monopolize the plane,
which is the deployment's misconfiguration, not the scheduler's job to
fix. The server handler models per-call *blocking* work
(``--service-us`` of sleep, floor'd by the OS timer at ~1.2ms: a
downstream I/O or device-kernel wait) — the component concurrent drain
workers overlap. Pure-Python marshalling cost cannot scale past the core
count under the GIL and is measured by bench-wire/bench-batch instead;
the regression guard for those single-channel paths is their own
unchanged gates.

Reported per worker count: aggregate calls/sec (completions inside the
measurement window / window), per-priority-tier p99 completion latency,
and the starvation check — the lowest-weight bulk channel must complete
calls (> 0) while the plane is saturated, which is exactly what DRR
guarantees and a naive hottest-first loop does not.

Acceptance (the ISSUE 5 gate): with 4 workers over the 5 channels,
aggregate calls/sec >= 2.5x the same-session ``workers=1`` baseline
(median of within-repeat ratios). Box-weather guard like async_latency:
when the gate fails, the workers=1 config is re-run against itself,
interleaved; if identical code cannot hold a 0.8 self-ratio the row
reports PASS-BASELINE-ALSO-FAILS (+ ``baseline_self_ratio=``) instead of
a bare FAIL.

    PYTHONPATH=src python -m benchmarks.multi_channel [--smoke] [--csv]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import threading
import time

import numpy as np

import repro.api as inc
from repro.api import DrainPolicy, IncRuntime
from benchmarks._util import write_bench_json

BULK_WEIGHTS = (8.0, 4.0, 2.0, 1.0)   # priority-0 tier, DRR shares
WORKER_SWEEP = (1, 2, 4)
GATE_X = 2.5                          # ISSUE 5: 4 workers >= 2.5x 1 worker
SERVICE_US = 500.0                    # per-call blocking handler work
HI_RATE = 100.0                       # paced latency-tier arrivals, calls/s
KEYS_PER_CALL = 8


def mk_services() -> list:
    """(label, schema class, priority, weight) per channel: one strict
    tier-1 latency channel + the weighted tier-0 bulk channels. Exercises
    the new ``@inc.service(priority=..., weight=...)`` annotations."""
    svcs = []

    @inc.service(app="shard-hi", name="ShardHi", priority=1,
                 drain=DrainPolicy(max_batch=8, max_delay=0.001,
                                   eager_window=False))
    class Hi:
        @inc.rpc(request_msg="R")
        def Push(self, kvs: inc.Agg[inc.STRINTMap], payload: inc.Plain
                 ) -> {"payload": inc.Plain}: ...

    svcs.append(("hi", Hi, 1, 1.0))
    for i, w in enumerate(BULK_WEIGHTS):
        @inc.service(app=f"shard-b{i}", name=f"ShardBulk{i}", weight=w,
                     drain=DrainPolicy(max_batch=16, max_delay=0.001,
                                       eager_window=False, weight=w))
        class Bulk:
            @inc.rpc(request_msg="R")
            def Push(self, kvs: inc.Agg[inc.STRINTMap], payload: inc.Plain
                     ) -> {"payload": inc.Plain}: ...

        svcs.append((f"b{i}", Bulk, 0, w))
    return svcs


def _requests(n: int, seed: int) -> list[dict]:
    rng = np.random.RandomState(seed)
    return [{"kvs": {f"f-{int(k)}": 1
                     for k in rng.zipf(1.3, KEYS_PER_CALL) % 512},
             "payload": "p"} for _ in range(n)]


def _drive(svcs: list, workers: int, duration: float,
           service_us: float) -> dict:
    """One measurement window: open-loop submitters on every channel for
    ``duration`` seconds; returns aggregate cps, per-priority p99, and
    per-channel completion counts (all restricted to completions inside
    the window — the drain tail after the deadline is excluded)."""
    service_s = service_us / 1e6
    rt = IncRuntime(workers=workers)
    rt.server.register(
        "Push", lambda r: (time.sleep(service_s), {"payload": "ok"})[1])
    stubs = [(label, rt.make_stub(svc), prio, w)
             for label, svc, prio, w in svcs]
    reqs = {label: _requests(256, seed=i)
            for i, (label, _, _, _) in enumerate(stubs)}
    records = {label: [] for label, _, _, _ in stubs}   # (done_ts, latency)

    # warm every channel (spawns the pool, grants map slots) off-clock
    for label, stub, _, _ in stubs:
        stub.Push(**reqs[label][0]).result()

    start = time.perf_counter()
    deadline = start + duration

    def submit_loop(label, stub, rate):
        """Open loop (rate=None: admission backpressure is the throttle)
        or paced arrivals at ``rate`` calls/s (the latency tier)."""
        rec = records[label]
        rs = reqs[label]
        i = 0
        while True:
            if rate is not None:
                target = start + i / rate
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            if time.perf_counter() >= deadline:
                break
            arr = time.perf_counter()
            f = stub.Push(**rs[i % len(rs)])    # blocks on admission
            f.add_done_callback(
                lambda fut, a=arr, r=rec:
                r.append((time.perf_counter(), time.perf_counter() - a)))
            i += 1

    threads = [threading.Thread(target=submit_loop,
                                args=(label, stub,
                                      HI_RATE if prio > 0 else None),
                                daemon=True)
               for label, stub, prio, _ in stubs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rt.drain()                      # flush the tail so close() is quick
    report = rt.scheduling_report()
    # --trace runs export the full obs snapshot before the runtime goes
    # away (per-channel drain-wait p99, switch CHR); None when obs is off
    snap = rt.metrics_snapshot() if inc.obs.enabled() else None
    rt.close()

    done_in_window = {label: [lat for ts, lat in records[label]
                              if ts <= deadline]
                      for label, _, _, _ in stubs}
    total = sum(len(v) for v in done_in_window.values())
    by_prio: dict[int, list] = {}
    for label, _, prio, _ in stubs:
        by_prio.setdefault(prio, []).extend(done_in_window[label])
    p99 = {p: (float(np.percentile(np.array(v) * 1e6, 99)) if v else 0.0)
           for p, v in by_prio.items()}
    return {"cps": total / duration,
            "p99_us_by_prio": p99,
            "completed": {label: len(v)
                          for label, v in done_in_window.items()},
            "plane": report.get("__plane__", {}),
            "snapshot": snap}


def run(duration: float = 0.8, repeats: int = 3,
        service_us: float = SERVICE_US) -> tuple[list, dict]:
    svcs_for = {w: mk_services() for w in WORKER_SWEEP}
    # schema classes hold compiled NetFilters keyed by AppName; channels
    # themselves are per-runtime (fresh Controller each _drive), so one
    # schema set per worker count is enough for the whole sweep
    samples = {w: [] for w in WORKER_SWEEP}
    low_label = f"b{len(BULK_WEIGHTS) - 1}"         # lowest DRR weight
    low_done = {w: [] for w in WORKER_SWEEP}        # per repeat
    detail = {}                                     # last repeat (p99s)
    for _ in range(repeats):
        # interleave worker counts per repeat so box jitter lands on
        # every config alike; the gate uses within-repeat ratios
        for w in WORKER_SWEEP:
            res = _drive(svcs_for[w], w, duration, service_us)
            samples[w].append(res["cps"])
            low_done[w].append(res["completed"].get(low_label, 0))
            detail[w] = res
    rows = []
    for w in WORKER_SWEEP:
        best = max(samples[w])
        res = detail[w]
        rows.append((f"t_shard/thr/workers{w}",
                     round(1e6 / best, 1) if best else 0,
                     f"agg_calls_per_sec={best:.0f}"
                     f" channels={len(svcs_for[w])}"))
        for p in sorted(res["p99_us_by_prio"], reverse=True):
            rows.append((f"t_shard/lat/workers{w}/prio{p}",
                         round(res["p99_us_by_prio"][p], 1),
                         f"p99_us={res['p99_us_by_prio'][p]:.0f}"))
        # starvation is judged over EVERY repeat, not whichever run the
        # other columns happen to report: the lowest-weight channel must
        # make progress in each saturated window
        starved = min(low_done[w]) == 0
        rows.append((f"t_shard/starvation/workers{w}", 0,
                     f"lowest_weight_completed_per_repeat={low_done[w]}"
                     f" ({'FAIL' if starved else 'PASS'})"
                     f" last_per_channel={res['completed']}"))
    ratios = [b / a for a, b in zip(samples[1], samples[4]) if a > 0]
    ratio = float(np.median(ratios)) if ratios else 0.0
    verdict = "PASS" if ratio >= GATE_X else "FAIL"
    baseline_note = ""
    self_ratio = None
    if verdict == "FAIL":
        # box-weather guard (see async_latency): identical workers=1 code
        # re-run against its own replay, interleaved — if the baseline
        # cannot hold steady against itself, the box failed the leg
        ctrl = {0: [], 1: []}
        for _ in range(max(2, repeats)):
            for leg in (0, 1):
                ctrl[leg].append(
                    _drive(svcs_for[1], 1, duration, service_us)["cps"])
        pairs = [a / b for a, b in zip(ctrl[0], ctrl[1]) if b > 0]
        self_ratio = float(np.median(pairs)) if pairs else 0.0
        stable = min(self_ratio, 1.0 / self_ratio) if self_ratio else 0.0
        baseline_note = f" baseline_self_ratio={self_ratio:.2f}"
        if stable < 0.8:
            verdict = "PASS-BASELINE-ALSO-FAILS"
    starvation_ok = all(min(low_done[w]) > 0 for w in WORKER_SWEEP)
    rows.append(("t_shard/acceptance", 0,
                 f"workers4_vs_workers1={ratio:.2f}x"
                 f" (need >= {GATE_X:.1f}x: {verdict})"
                 f" starvation_check={'PASS' if starvation_ok else 'FAIL'}"
                 f"{baseline_note}"))
    acceptance = {
        "workers4_vs_workers1": round(ratio, 3),
        "target": GATE_X,
        "verdict": verdict,
        "starvation_check": "PASS" if starvation_ok else "FAIL",
    }
    if self_ratio is not None:
        acceptance["baseline_self_ratio"] = round(self_ratio, 3)
    return rows, acceptance


def _traced_window(duration: float, service_us: float) -> None:
    """``--trace``: one fully-observed workers=4 saturation window. The
    span timeline (queued -> drain -> plane_lock -> pipeline phases ->
    switch ops, one track per channel) lands in
    benchmarks/TRACE_multi_channel.json — load it in Perfetto / Chrome
    ``about:tracing`` — and the per-channel drain-wait p99 + switch CHR
    come straight out of ``metrics_snapshot()``."""
    from pathlib import Path
    inc.obs.enable(trace=True, trace_stride=4)
    try:
        res = _drive(mk_services(), 4, duration, service_us)
        snap = res["snapshot"]
        out = Path(__file__).resolve().parent / "TRACE_multi_channel.json"
        inc.obs.write_trace(out)
        print(f"trace: {len(inc.obs.tracer())} events -> {out}")
        for app, ch in sorted(snap["channels"].items()):
            print(f"{app}: drain_wait_p99_us="
                  f"{ch.get('drain_wait_p99_us', 0.0)}"
                  f" latency_p99_us={ch.get('latency_p99_us', 0.0)}"
                  f" CHR="
                  f"{snap['switch']['apps'][app]['cache_hit_ratio']:.3f}")
    finally:
        inc.obs.disable()
        inc.obs.reset()


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    ap.add_argument("--csv", action="store_true",
                    help="append the rows to benchmarks/results.csv")
    ap.add_argument("--trace", action="store_true",
                    help="one traced workers=4 window instead of the sweep:"
                         " writes benchmarks/TRACE_multi_channel.json"
                         " (Perfetto-loadable) + the obs snapshot summary")
    ap.add_argument("--duration", type=float, default=0.8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--service-us", type=float, default=SERVICE_US)
    args = ap.parse_args()
    duration = 0.4 if args.smoke else args.duration
    repeats = 1 if args.smoke else args.repeats
    if args.trace:
        _traced_window(duration, args.service_us)
        return
    rows, acceptance = run(duration, repeats, args.service_us)
    lines = [",".join(str(x) for x in row) for row in rows]
    for ln in lines:
        print(ln)
    # smoke runs export under a separate (gitignored) name so CI never
    # overwrites the committed full-run trajectory with tiny-n noise
    write_bench_json("smoke_multi_channel" if args.smoke
                     else "multi_channel",
                     {"duration": duration, "repeats": repeats,
                      "service_us": args.service_us,
                      "workers": list(WORKER_SWEEP),
                      "bulk_weights": list(BULK_WEIGHTS),
                      "smoke": args.smoke},
                     rows, acceptance)
    if args.csv:
        from pathlib import Path
        out = Path(__file__).resolve().parent / "results.csv"
        with out.open("a") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
