"""Table 5 analogue: basic INC function microbenchmarks.

SyncAgtr / AsyncAgtr goodput over the host-device data plane (8 devices,
2 DP ranks x 4 TP — wall time on one CPU core is NOT TPU-representative;
the derived column also reports modeled wire bytes, the
hardware-independent quantity the roofline consumes). Voting and Monitor
delays come from the host-level CntFwd / INC-map paths.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks._util import host_mesh, timeit
from repro.core import inc_agg
from repro.core.agreement import CntFwd
from repro.core.inc_agg import IncAggConfig
from repro.core.inc_map import ServerAgent, SwitchMemory

L = 1 << 20      # 1M fp32 elements per rank


def _allreduce_fn(mesh, mode):
    cfg = IncAggConfig(mode=mode, precision=8)
    manual = ("data",)

    def body(g):
        out, _ = inc_agg.all_reduce(g, manual, cfg)
        return out

    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P(),
                                 out_specs=P(), axis_names={"data"},
                                 check_vma=False))


def run():
    rows = []
    mesh = host_mesh(model=2)
    n_dp = mesh.shape["data"]
    g = jnp.asarray(np.random.RandomState(0).randn(L).astype(np.float32))
    for mode in ("xla-psum", "fp32-ring", "netrpc", "netrpc-opt"):
        f = _allreduce_fn(mesh, mode)
        us = timeit(f, g)
        bytes_moved = {"xla-psum": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "fp32-ring": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "netrpc": (2 * 4 + 2 * 4) * L * (n_dp - 1) / n_dp,
                       "netrpc-opt": 2 * 2 * L * (n_dp - 1) / n_dp}[mode]
        rows.append((f"t5/syncagtr_allreduce/{mode}", round(us, 1),
                     f"wire_bytes_per_rank={bytes_moved:.0f}"))

    # AsyncAgtr: keyed sparse aggregation through the INC map
    srv = ServerAgent(SwitchMemory(4, 4096), gaid=1, n_slots=8192)
    rng = np.random.RandomState(1)
    keys = rng.zipf(1.3, 4096).astype(np.uint32) % 8192
    vals = rng.randint(1, 100, 4096)
    import time as _t
    t0 = _t.perf_counter()
    for _ in range(8):
        srv.addto_batch(keys, vals)
    us = (_t.perf_counter() - t0) / 8 * 1e6
    rows.append(("t5/asyncagtr_addto_batch4096", round(us, 1),
                 f"chr={srv.cache_hit_ratio:.3f}"))

    # Voting delay (CntFwd, sub-RTT switch path)
    cf = CntFwd(server=ServerAgent(SwitchMemory(1, 512), 2, 256),
                threshold=3)
    t0 = _t.perf_counter()
    n = 300
    for i in range(n):
        cf.offer(i % 50)
    us = (_t.perf_counter() - t0) / n * 1e6
    rows.append(("t5/voting_delay", round(us, 1), "per_offer"))

    # Monitor delay (KeyValue read path)
    t0 = _t.perf_counter()
    for i in range(200):
        srv.read(int(keys[i]))
    us = (_t.perf_counter() - t0) / 200 * 1e6
    rows.append(("t5/monitor_read_delay", round(us, 1), "per_read"))
    return rows
