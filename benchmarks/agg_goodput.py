"""Table 5 analogue: basic INC function microbenchmarks.

SyncAgtr / AsyncAgtr goodput over the host-device data plane (8 devices,
2 DP ranks x 4 TP — wall time on one CPU core is NOT TPU-representative;
the derived column also reports modeled wire bytes, the
hardware-independent quantity the roofline consumes). Voting and Monitor
delays come from the host-level CntFwd / INC-map paths.

``--batch`` runs the batched-RPC sweep instead: calls/sec of the bulk
data plane (typed-stub ``Push.batch``, inline call_batch_async) vs batch
size (one sparse_addto kernel batch per flush instead of one device round
trip per call):

    PYTHONPATH=src python -m benchmarks.agg_goodput --batch
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks._util import host_mesh, timeit
import repro.api as inc
from repro.core import inc_agg
from repro import compat
from repro.core.agreement import CntFwd
from repro.core.inc_agg import IncAggConfig
from repro.core.inc_map import ServerAgent, SwitchMemory

L = 1 << 20      # 1M fp32 elements per rank


def _allreduce_fn(mesh, mode):
    cfg = IncAggConfig(mode=mode, precision=8)
    manual = ("data",)

    def body(g):
        out, _ = inc_agg.all_reduce(g, manual, cfg)
        return out

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(),
                                 out_specs=P(), axis_names={"data"},
                                 check_vma=False))


def run():
    rows = []
    mesh = host_mesh(model=2)
    n_dp = mesh.shape["data"]
    g = jnp.asarray(np.random.RandomState(0).randn(L).astype(np.float32))
    for mode in ("xla-psum", "fp32-ring", "netrpc", "netrpc-opt"):
        f = _allreduce_fn(mesh, mode)
        us = timeit(f, g)
        bytes_moved = {"xla-psum": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "fp32-ring": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "netrpc": (2 * 4 + 2 * 4) * L * (n_dp - 1) / n_dp,
                       "netrpc-opt": 2 * 2 * L * (n_dp - 1) / n_dp}[mode]
        rows.append((f"t5/syncagtr_allreduce/{mode}", round(us, 1),
                     f"wire_bytes_per_rank={bytes_moved:.0f}"))

    # AsyncAgtr: keyed sparse aggregation through the INC map
    srv = ServerAgent(SwitchMemory(4, 4096), gaid=1, n_slots=8192)
    rng = np.random.RandomState(1)
    keys = rng.zipf(1.3, 4096).astype(np.uint32) % 8192
    vals = rng.randint(1, 100, 4096)
    import time as _t
    t0 = _t.perf_counter()
    for _ in range(8):
        srv.addto_batch(keys, vals)
    us = (_t.perf_counter() - t0) / 8 * 1e6
    rows.append(("t5/asyncagtr_addto_batch4096", round(us, 1),
                 f"chr={srv.cache_hit_ratio:.3f}"))

    # Voting delay (CntFwd, sub-RTT switch path)
    cf = CntFwd(server=ServerAgent(SwitchMemory(1, 512), 2, 256),
                threshold=3)
    t0 = _t.perf_counter()
    n = 300
    for i in range(n):
        cf.offer(i % 50)
    us = (_t.perf_counter() - t0) / n * 1e6
    rows.append(("t5/voting_delay", round(us, 1), "per_offer"))

    # Monitor delay (KeyValue read path)
    t0 = _t.perf_counter()
    for i in range(200):
        srv.read(int(keys[i]))
    us = (_t.perf_counter() - t0) / 200 * 1e6
    rows.append(("t5/monitor_read_delay", round(us, 1), "per_read"))
    return rows


# -- batched RPC data-plane sweep (ISSUE 1 tentpole) --------------------------

KEYS_PER_CALL = 16


# Monitoring-style RPC with a vote counter: exercises the full request
# pipeline the batch plane vectorizes — Map.addTo for the kvs stream plus
# a CntFwd counter per call (ballot = the hottest flow key).
@inc.service(app="BB-1")
class BatchBench:
    @inc.rpc(request_msg="PushRequest",
             cnt_fwd=inc.CntFwd(to="SRC", threshold=1 << 30,
                                key="PushRequest.kvs"))
    def Push(self, kvs: inc.Agg[inc.STRINTMap]) -> {"msg": inc.Plain}: ...


def _batch_requests(n_calls: int, seed: int = 0) -> list[dict]:
    rng = np.random.RandomState(seed)
    return [{"kvs": {f"flow-{int(k)}": 1
                     for k in rng.zipf(1.3, KEYS_PER_CALL) % 2048}}
            for _ in range(n_calls)]


def run_batch(batch_sizes=(1, 4, 16, 64), n_calls: int = 256,
              repeats: int = 5) -> list:
    """calls/sec of the batched pipeline vs batch size, same total work.

    Every sweep point replays the identical request stream on a fresh
    runtime, chunked into call_batch(batch) groups; batch=1 is the
    sequential Stub.call path (the N=1 special case of the same pipeline).
    Each point reports the fastest of ``repeats`` timed replays (gc paused
    during timing): min is the least-noise estimator on a shared/jittery
    host, and both sweep points get the same treatment.
    """
    import gc
    rows = []
    base_cps = None
    for bs in batch_sizes:
        times = []
        for rep in range(repeats):
            rt = inc.NetRPC()
            stub = rt.make_stub(BatchBench, n_slots=8192)
            reqs = _batch_requests(n_calls)
            # warm the jit caches (sparse_addto buckets) for this chunk size
            for chunk in _chunks(_batch_requests(4 * bs, seed=1), bs):
                stub.Push.batch(chunk)
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                for chunk in _chunks(reqs, bs):
                    # inline bulk submission: one pipeline pass per chunk,
                    # futures come back already resolved
                    stub.Push.batch(chunk)
                times.append(time.perf_counter() - t0)
            finally:
                gc.enable()
        dt = min(times)
        cps = n_calls / dt
        base_cps = base_cps or cps
        rows.append((f"t5/batch_sweep/bs{bs}",
                     round(dt / n_calls * 1e6, 1),
                     f"calls_per_sec={cps:.0f}"
                     f" speedup_vs_bs1={cps / base_cps:.2f}x"))
        last_speedup = cps / base_cps
    acceptance = {"speedup_at_max_bs": round(last_speedup, 2),
                  "max_bs": batch_sizes[-1], "target": 5.0,
                  "verdict": "PASS" if last_speedup >= 5.0 else "FAIL"}
    return rows, acceptance


def _chunks(seq, n):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


# -- client-side local aggregation sweep (local_accum=N, ISSUE 9) -------------

ACCUM_SWEEP = (1, 2, 4, 8)


def _accum_service(app: str, accum: int):
    """AccumBench: the fold-eligible twin of BatchBench — same keyed
    Map.addTo stream, no CntFwd (local_accum rejects per-call counters:
    a folded cohort is one switch op, so per-call vote semantics cannot
    survive the fold). Annotations are assigned explicitly: this module
    postpones annotations, so a closure-parameterized spec inside a
    decorated class body would not resolve."""
    def Push(self, kvs): ...
    Push.__annotations__ = {
        "kvs": inc.Agg[inc.STRINTMap](local_accum=accum),
        "return": {"msg": inc.Plain}}
    Push = inc.rpc(request_msg="PushRequest")(Push)

    def Query(self, kvs): ...
    Query.__annotations__ = {"kvs": inc.ReadMostly[inc.STRINTMap]}
    Query = inc.rpc(Query)

    cls = type("AccumBench", (), {"Push": Push, "Query": Query})
    return inc.service(app=app, name="AccumBench")(cls)


def _accum_device_service(app: str, accum: int):
    def Push(self, grads): ...
    Push.__annotations__ = {
        "grads": inc.Agg[inc.FPArray](precision=6, device=True,
                                      local_accum=accum),
        "return": {"grads": inc.Get[inc.FPArray]}}
    Push = inc.rpc(request_msg="GradPush")(Push)
    cls = type("AccumDev", (), {"Push": Push})
    return inc.service(app=app, name="AccumDev")(cls)


def _verify_accum_exact(accum: int) -> dict:
    """Element-exact differential: the folded client (local_accum=N) must
    leave the switch in the SAME state as N separate addTo calls — host
    dict lane and device tensor lane both. Returns the per-lane verdicts
    consumed by the acceptance block."""
    reqs = _batch_requests(64, seed=3)
    keys = sorted({k for r in reqs for k in r["kvs"]})
    host = []
    for a in (1, accum):
        rt = inc.NetRPC()
        stub = rt.make_stub(_accum_service(f"AB-V{a}", a), n_slots=8192)
        for r in reqs:
            stub.Push(kvs=r["kvs"])
        rt.drain()
        host.append(stub.Query(kvs={k: 0 for k in keys}).result()["kvs"])
    rng = np.random.RandomState(5)
    rounds = [rng.randn(256).astype(np.float32) for _ in range(16)]
    dev = []
    for a in (1, accum):
        rt = inc.NetRPC()
        stub = rt.make_stub(_accum_device_service(f"AD-V{a}", a),
                            n_slots=512)
        for x in rounds:
            stub.Push(grads=x)
        rt.drain()
        dev.append(np.asarray(
            stub.Push(grads=np.zeros(256, np.float32)).result()["grads"]))
    return {"host_exact": host[0] == host[1],
            "device_exact": bool(np.array_equal(dev[0], dev[1]))}


def run_accum(accums=ACCUM_SWEEP, n_calls: int = 256, repeats: int = 5,
              committed: dict | None = None):
    """Effective calls/sec of the per-call submission path vs local_accum.

    Every sweep point replays the identical per-call Push stream (the
    fold front: one call per submission, not .batch) on a fresh runtime;
    accum=1 is the unfolded oracle — every call is one pipeline pass.
    Min-of-repeats with gc paused, like run_batch.

    Gate: >= 3x effective calls/sec at local_accum=8, with the element-
    exact differential green on both lanes. If the gate fails, the
    committed BENCH_agg_accum.json (when present) arbitrates box weather:
    a baseline accum=1 leg that also degraded >30% vs its committed
    calls/sec means the host slowed down, not the fold path — verdict
    PASS-BASELINE-ALSO-FAILS rather than FAIL.
    """
    import gc
    rows = []
    base_cps = None
    cps_by_accum = {}
    for a in accums:
        times = []
        reduction = None
        for rep in range(repeats):
            rt = inc.NetRPC()
            stub = rt.make_stub(_accum_service(f"AB-{a}", a), n_slots=8192)
            reqs = _batch_requests(n_calls)
            # warm the jit/merge caches at this fold depth
            for r in _batch_requests(4 * a, seed=1):
                stub.Push(kvs=r["kvs"])
            rt.drain()
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                for r in reqs:
                    stub.Push(kvs=r["kvs"])
                rt.drain()          # flush the tail fold
                times.append(time.perf_counter() - t0)
            finally:
                gc.enable()
            st = rt.controller.lookup(f"AB-{a}").stats
            reduction = ((st.calls - st.flushes + st.local_folds)
                         / st.calls if st.calls else 1.0)
        dt = min(times)
        cps = n_calls / dt
        cps_by_accum[a] = cps
        base_cps = base_cps or cps
        rows.append((f"t5/accum_sweep/accum{a}",
                     round(dt / n_calls * 1e6, 1),
                     f"calls_per_sec={cps:.0f}"
                     f" speedup_vs_accum1={cps / base_cps:.2f}x"
                     f" traffic_reduction={reduction:.2f}"))
    speedup = cps_by_accum[accums[-1]] / cps_by_accum[accums[0]]
    exact = _verify_accum_exact(accums[-1])
    ok = speedup >= 3.0 and exact["host_exact"] and exact["device_exact"]
    verdict = "PASS" if ok else "FAIL"
    if not ok and committed:
        # box-weather arbitration: compare our unfolded leg against the
        # committed run's — only a perf miss with a healthy baseline is a
        # real regression (exactness failures are never excused)
        old = _committed_cps(committed, f"accum{accums[0]}")
        if (exact["host_exact"] and exact["device_exact"] and old
                and cps_by_accum[accums[0]] < 0.7 * old):
            verdict = "PASS-BASELINE-ALSO-FAILS"
    acceptance = {"speedup_at_max_accum": round(speedup, 2),
                  "max_accum": accums[-1], "target": 3.0,
                  **exact, "verdict": verdict}
    return rows, acceptance


def _committed_cps(committed: dict, leg: str) -> float | None:
    for row in committed.get("rows", []):
        if row["metric"].endswith(leg):
            for tok in row["note"].split():
                if tok.startswith("calls_per_sec="):
                    return float(tok.split("=", 1)[1])
    return None


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", action="store_true",
                    help="run the batched-RPC calls/sec sweep")
    ap.add_argument("--local-accum", action="store_true",
                    help="run the client-side local aggregation sweep "
                         f"(local_accum in {ACCUM_SWEEP})")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iterations; writes the gitignored "
                         "BENCH_smoke_* variant")
    args = ap.parse_args()
    from benchmarks._util import write_bench_json
    if args.batch:
        rows, acceptance = run_batch()
        write_bench_json("agg_batch", {"sweep": "batch"}, rows, acceptance)
    elif args.local_accum:
        import json
        from pathlib import Path
        committed = None
        ref = Path(__file__).resolve().parent / "BENCH_agg_accum.json"
        if ref.exists():
            committed = json.loads(ref.read_text())
        if args.smoke:
            rows, acceptance = run_accum(n_calls=64, repeats=2,
                                         committed=committed)
            write_bench_json("smoke_agg_accum",
                             {"sweep": "local_accum", "smoke": True},
                             rows, acceptance)
        else:
            rows, acceptance = run_accum(committed=committed)
            write_bench_json("agg_accum", {"sweep": "local_accum"},
                             rows, acceptance)
        print(f"verdict: {acceptance['verdict']} "
              f"(speedup_at_max_accum={acceptance['speedup_at_max_accum']}x,"
              f" host_exact={acceptance['host_exact']},"
              f" device_exact={acceptance['device_exact']})")
    else:
        rows = run()
    for row in rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
