"""Table 5 analogue: basic INC function microbenchmarks.

SyncAgtr / AsyncAgtr goodput over the host-device data plane (8 devices,
2 DP ranks x 4 TP — wall time on one CPU core is NOT TPU-representative;
the derived column also reports modeled wire bytes, the
hardware-independent quantity the roofline consumes). Voting and Monitor
delays come from the host-level CntFwd / INC-map paths.

``--batch`` runs the batched-RPC sweep instead: calls/sec of the bulk
data plane (typed-stub ``Push.batch``, inline call_batch_async) vs batch
size (one sparse_addto kernel batch per flush instead of one device round
trip per call):

    PYTHONPATH=src python -m benchmarks.agg_goodput --batch
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks._util import host_mesh, timeit
import repro.api as inc
from repro.core import inc_agg
from repro import compat
from repro.core.agreement import CntFwd
from repro.core.inc_agg import IncAggConfig
from repro.core.inc_map import ServerAgent, SwitchMemory

L = 1 << 20      # 1M fp32 elements per rank


def _allreduce_fn(mesh, mode):
    cfg = IncAggConfig(mode=mode, precision=8)
    manual = ("data",)

    def body(g):
        out, _ = inc_agg.all_reduce(g, manual, cfg)
        return out

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(),
                                 out_specs=P(), axis_names={"data"},
                                 check_vma=False))


def run():
    rows = []
    mesh = host_mesh(model=2)
    n_dp = mesh.shape["data"]
    g = jnp.asarray(np.random.RandomState(0).randn(L).astype(np.float32))
    for mode in ("xla-psum", "fp32-ring", "netrpc", "netrpc-opt"):
        f = _allreduce_fn(mesh, mode)
        us = timeit(f, g)
        bytes_moved = {"xla-psum": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "fp32-ring": 2 * 4 * L * (n_dp - 1) / n_dp,
                       "netrpc": (2 * 4 + 2 * 4) * L * (n_dp - 1) / n_dp,
                       "netrpc-opt": 2 * 2 * L * (n_dp - 1) / n_dp}[mode]
        rows.append((f"t5/syncagtr_allreduce/{mode}", round(us, 1),
                     f"wire_bytes_per_rank={bytes_moved:.0f}"))

    # AsyncAgtr: keyed sparse aggregation through the INC map
    srv = ServerAgent(SwitchMemory(4, 4096), gaid=1, n_slots=8192)
    rng = np.random.RandomState(1)
    keys = rng.zipf(1.3, 4096).astype(np.uint32) % 8192
    vals = rng.randint(1, 100, 4096)
    import time as _t
    t0 = _t.perf_counter()
    for _ in range(8):
        srv.addto_batch(keys, vals)
    us = (_t.perf_counter() - t0) / 8 * 1e6
    rows.append(("t5/asyncagtr_addto_batch4096", round(us, 1),
                 f"chr={srv.cache_hit_ratio:.3f}"))

    # Voting delay (CntFwd, sub-RTT switch path)
    cf = CntFwd(server=ServerAgent(SwitchMemory(1, 512), 2, 256),
                threshold=3)
    t0 = _t.perf_counter()
    n = 300
    for i in range(n):
        cf.offer(i % 50)
    us = (_t.perf_counter() - t0) / n * 1e6
    rows.append(("t5/voting_delay", round(us, 1), "per_offer"))

    # Monitor delay (KeyValue read path)
    t0 = _t.perf_counter()
    for i in range(200):
        srv.read(int(keys[i]))
    us = (_t.perf_counter() - t0) / 200 * 1e6
    rows.append(("t5/monitor_read_delay", round(us, 1), "per_read"))
    return rows


# -- batched RPC data-plane sweep (ISSUE 1 tentpole) --------------------------

KEYS_PER_CALL = 16


# Monitoring-style RPC with a vote counter: exercises the full request
# pipeline the batch plane vectorizes — Map.addTo for the kvs stream plus
# a CntFwd counter per call (ballot = the hottest flow key).
@inc.service(app="BB-1")
class BatchBench:
    @inc.rpc(request_msg="PushRequest",
             cnt_fwd=inc.CntFwd(to="SRC", threshold=1 << 30,
                                key="PushRequest.kvs"))
    def Push(self, kvs: inc.Agg[inc.STRINTMap]) -> {"msg": inc.Plain}: ...


def _batch_requests(n_calls: int, seed: int = 0) -> list[dict]:
    rng = np.random.RandomState(seed)
    return [{"kvs": {f"flow-{int(k)}": 1
                     for k in rng.zipf(1.3, KEYS_PER_CALL) % 2048}}
            for _ in range(n_calls)]


def run_batch(batch_sizes=(1, 4, 16, 64), n_calls: int = 256,
              repeats: int = 5) -> list:
    """calls/sec of the batched pipeline vs batch size, same total work.

    Every sweep point replays the identical request stream on a fresh
    runtime, chunked into call_batch(batch) groups; batch=1 is the
    sequential Stub.call path (the N=1 special case of the same pipeline).
    Each point reports the fastest of ``repeats`` timed replays (gc paused
    during timing): min is the least-noise estimator on a shared/jittery
    host, and both sweep points get the same treatment.
    """
    import gc
    rows = []
    base_cps = None
    for bs in batch_sizes:
        times = []
        for rep in range(repeats):
            rt = inc.NetRPC()
            stub = rt.make_stub(BatchBench, n_slots=8192)
            reqs = _batch_requests(n_calls)
            # warm the jit caches (sparse_addto buckets) for this chunk size
            for chunk in _chunks(_batch_requests(4 * bs, seed=1), bs):
                stub.Push.batch(chunk)
            gc.collect()
            gc.disable()
            try:
                t0 = time.perf_counter()
                for chunk in _chunks(reqs, bs):
                    # inline bulk submission: one pipeline pass per chunk,
                    # futures come back already resolved
                    stub.Push.batch(chunk)
                times.append(time.perf_counter() - t0)
            finally:
                gc.enable()
        dt = min(times)
        cps = n_calls / dt
        base_cps = base_cps or cps
        rows.append((f"t5/batch_sweep/bs{bs}",
                     round(dt / n_calls * 1e6, 1),
                     f"calls_per_sec={cps:.0f}"
                     f" speedup_vs_bs1={cps / base_cps:.2f}x"))
        last_speedup = cps / base_cps
    acceptance = {"speedup_at_max_bs": round(last_speedup, 2),
                  "max_bs": batch_sizes[-1], "target": 5.0,
                  "verdict": "PASS" if last_speedup >= 5.0 else "FAIL"}
    return rows, acceptance


def _chunks(seq, n):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", action="store_true",
                    help="run the batched-RPC calls/sec sweep")
    args = ap.parse_args()
    if args.batch:
        rows, acceptance = run_batch()
        from benchmarks._util import write_bench_json
        write_bench_json("agg_batch", {"sweep": "batch"}, rows, acceptance)
    else:
        rows = run()
    for row in rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
