"""Figure 7 analogue: Paxos throughput and p99 commit latency.

INC variant (CntFwd counts votes, learners see only majority commits) vs a
pure-software baseline where every accept travels to the learner process
(the libpaxos analogue).
"""
from __future__ import annotations

import time

import numpy as np

import repro.api as inc

N_PROPOSALS = 150
MAJORITY = 2
N_ACCEPTORS = 3


def _service(use_inc: bool):
    """Typed schema per variant: with INC, CntFwd counts the accepts in-
    network; without, threshold=0 disables the gate and every accept
    travels to the learner (the libpaxos analogue)."""
    cnt = (inc.CntFwd(to="ALL", threshold=MAJORITY, key="kvs") if use_inc
           else inc.CntFwd(to="SRC", threshold=0))

    @inc.service(app=f"paxos-{use_inc}", name="Paxos")
    class Paxos:
        @inc.rpc(cnt_fwd=cnt)
        def Accept(self, kvs: inc.STRINTMap) -> {"msg": inc.Plain}: ...
    return Paxos


def _drive(use_inc: bool):
    svc = _service(use_inc)
    rt = inc.NetRPC()
    learned = []
    if use_inc:
        rt.server.register("Accept",
                           lambda req: learned.append(1) or {"msg": "ok"})
    else:
        # software learner counts votes itself
        votes: dict = {}

        def handler(req):
            # passthrough fields only; count per call
            votes["n"] = votes.get("n", 0) + 1
            if votes["n"] % N_ACCEPTORS >= MAJORITY or \
                    votes["n"] % N_ACCEPTORS == 0:
                learned.append(1)
            return {"msg": "ok"}
        rt.server.register("Accept", handler)
    acceptors = [rt.make_stub(svc) for _ in range(N_ACCEPTORS)]
    lats = []
    t0 = time.time()
    for b in range(N_PROPOSALS):
        t1 = time.perf_counter()
        for a in acceptors:
            a.Accept(kvs={f"b{b}": 1}).result()
        lats.append(time.perf_counter() - t1)
    dt = time.time() - t0
    return N_PROPOSALS / dt, np.percentile(lats, 99) * 1e6, \
        rt.server.calls_seen


def run():
    rows = []
    thr_inc, p99_inc, seen_inc = _drive(use_inc=True)
    thr_sw, p99_sw, seen_sw = _drive(use_inc=False)
    rows.append(("f7/inc/throughput_per_s", round(1e6 / thr_inc, 1),
                 round(thr_inc, 1)))
    rows.append(("f7/inc/p99_us", round(p99_inc, 1),
                 f"server_msgs={seen_inc}"))
    rows.append(("f7/software/throughput_per_s", round(1e6 / thr_sw, 1),
                 round(thr_sw, 1)))
    rows.append(("f7/software/p99_us", round(p99_sw, 1),
                 f"server_msgs={seen_sw}"))
    return rows
