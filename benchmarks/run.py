"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name ...]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract and
writes benchmarks/results.csv. Benchmarks that exercise multi-flow INC
behavior (goodput, fairness, train speed) run over 8 forced host devices —
set here, at the single explicit entry point, never globally.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import csv
import importlib
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MODULES = [
    "loc_table",          # Table 4
    "agg_goodput",        # Table 5
    "train_speed",        # Figure 6
    "paxos_bench",        # Figure 7
    "congestion",         # Figures 8-9
    "loss_robustness",    # Figure 10
    "overflow_sweep",     # Figure 11
    "cache_policies",     # Figure 12
    "multiswitch",        # Figure 13
    "clear_policies",     # Table 6
    "multi_app",          # Table 7
    "async_latency",      # PR 2 auto-drain triggers (latency/throughput)
    "wire_path",          # PR 4 GPV wire format (dict vs array marshalling)
    "multi_channel",      # PR 5 sharded plane (workers sweep + fairness)
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    mods = args.only or MODULES
    rows = [("name", "us_per_call", "derived")]
    failed = []
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            out = mod.run()
            if isinstance(out, tuple):      # (rows, acceptance) benches
                out = out[0]
            rows.extend(out)
            print(f"# {name}: {len(out)} rows ({time.time() - t0:.1f}s)",
                  file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    for r in rows:
        print(",".join(str(x) for x in r))
    with open(Path(__file__).parent / "results.csv", "w", newline="") as f:
        csv.writer(f).writerows(rows)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
