"""Observability overhead gate (ISSUE 7): what does ``repro.obs`` cost?

Three interleaved legs replay the identical bulk data-plane workload
(the ``stub.Push.batch`` hot path from agg_goodput's batch sweep, bs=64,
fresh ``inc.NetRPC()`` per replay):

  off       obs fully disabled — the baseline.
  disabled  obs fully disabled AGAIN. The instrumented call sites compile
            down to one module-global load + branch when off, so this leg
            runs byte-identical code to the baseline: the measured delta
            IS the box's timing noise floor, and the <= 2% gate asserts
            the disabled mode is indistinguishable from no obs at all.
  on        ``obs.enable(trace=True, trace_stride=16)`` — per-batch
            metrics plus sampled span tracing; gate <= 10% vs baseline.

Legs interleave per repeat so box jitter lands on every mode alike; each
mode reports the fastest of ``repeats`` replays (min is the least-noise
estimator on a shared host, and all three legs get the same treatment).
Box-weather guard like multi_channel: when a gate fails, two extra off
legs re-run interleaved — if identical code cannot hold a 2% self-ratio
the row reports PASS-BASELINE-ALSO-FAILS instead of a bare FAIL.

A fourth (untimed) leg runs a traced ``IncRuntime(workers=2)`` workload
and validates the exports end-to-end: ``metrics_snapshot()`` against the
checked-in ``scripts/obs_schema.json`` (per-channel submit->resolve p99
and the switch CHR must be readable), and the Chrome trace JSON via
``repro.obs.trace.validate_chrome_trace``.

    PYTHONPATH=src python -m benchmarks.obs_overhead [--smoke]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import gc
import time

import repro.api as inc
from repro.obs import schema as obs_schema
from repro.obs.trace import validate_chrome_trace
from benchmarks._util import write_bench_json
from benchmarks.agg_goodput import BatchBench, _batch_requests, _chunks

BS = 64                      # the batch sweep's best-throughput point
DISABLED_GATE_PCT = 2.0      # obs compiled out when off
ENABLED_GATE_PCT = 10.0      # sampled tracing + metrics on the hot path
TRACE_STRIDE = 16


def _time_leg(n_calls: int) -> float:
    """One timed replay of the bulk hot path under the CURRENT obs mode:
    fresh runtime, warmed jit caches, gc paused — agg_goodput's
    run_batch protocol at bs=64."""
    rt = inc.NetRPC()
    stub = rt.make_stub(BatchBench, n_slots=8192)
    reqs = _batch_requests(n_calls)
    for chunk in _chunks(_batch_requests(4 * BS, seed=1), BS):
        stub.Push.batch(chunk)
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for chunk in _chunks(reqs, BS):
            stub.Push.batch(chunk)
        return time.perf_counter() - t0
    finally:
        gc.enable()


def _set_mode(mode: str) -> None:
    if mode == "on":
        inc.obs.enable(trace=True, trace_stride=TRACE_STRIDE)
    else:
        inc.obs.disable()


MODES = ("off", "disabled", "on")


def run_legs(n_calls: int, repeats: int) -> dict[str, float]:
    """min-of-repeats seconds per mode, legs interleaved per repeat. The
    order ROTATES per repeat (off/disabled/on, disabled/on/off, ...): a
    fixed order hands the same slot of any within-repeat drift (allocator
    state, thermal ramp) to the same mode every time, which showed up as
    a phantom ~5% 'overhead' on identical code."""
    times: dict[str, list[float]] = {m: [] for m in MODES}
    for rep in range(repeats):
        k = rep % len(MODES)
        for mode in MODES[k:] + MODES[:k]:
            _set_mode(mode)
            try:
                times[mode].append(_time_leg(n_calls))
            finally:
                # drop the sampled ring + registry deltas between legs so
                # the enabled leg never times against a half-full ring
                inc.obs.disable()
                inc.obs.reset()
    return {m: min(ts) for m, ts in times.items()}


def _self_ratio(n_calls: int, repeats: int) -> float:
    """Box-weather control: two interleaved off-mode legs of identical
    code; returns min(r, 1/r) — 1.0 on a quiet box."""
    ctrl: dict[int, list[float]] = {0: [], 1: []}
    inc.obs.disable()
    for _ in range(max(2, repeats)):
        for leg in (0, 1):
            ctrl[leg].append(_time_leg(n_calls))
    a, b = min(ctrl[0]), min(ctrl[1])
    r = a / b if b else 0.0
    return min(r, 1.0 / r) if r else 0.0


def _validate_exports(n_calls: int) -> tuple[int, dict]:
    """The untimed correctness leg: traced async runtime workload; raises
    unless the snapshot matches scripts/obs_schema.json, the quantile /
    CHR keys the ISSUE promises are readable, and the Chrome trace
    validates. Returns (n_trace_events, snapshot)."""
    inc.obs.enable(trace=True, trace_stride=1)
    try:
        with inc.IncRuntime(workers=2) as rt:
            stub = rt.make_stub(BatchBench, n_slots=8192)
            futs = [stub.Push(**req) for req in _batch_requests(n_calls)]
            rt.drain()
            for f in futs:
                f.result()
            snap = rt.metrics_snapshot()
        obs_schema.validate(snap,
                            obs_schema.load(obs_schema.repo_schema_path()))
        ch = snap["channels"]["BB-1"]
        for key in ("latency_p50_us", "latency_p99_us",
                    "drain_wait_p50_us", "drain_wait_p99_us"):
            if key not in ch:
                raise AssertionError(f"channel entry missing {key}")
        chr_ = snap["switch"]["apps"]["BB-1"]["cache_hit_ratio"]
        if not (0.0 <= chr_ <= 1.0):
            raise AssertionError(f"cache_hit_ratio out of range: {chr_}")
        trace_doc = inc.obs.chrome_trace()
        validate_chrome_trace(trace_doc)
        n_events = len(trace_doc["traceEvents"])
        if n_events == 0:
            raise AssertionError("traced run recorded no events")
        return n_events, snap
    finally:
        inc.obs.disable()
        inc.obs.reset()


def run(n_calls: int = 256, repeats: int = 5) -> tuple[list, dict]:
    inc.obs.disable()        # REPRO_OBS=1 must not skew the baseline leg
    inc.obs.reset()
    best = run_legs(n_calls, repeats)
    base = best["off"]
    pct = {m: (best[m] / base - 1.0) * 100.0 if base else 0.0
           for m in ("disabled", "on")}
    gates = {"disabled": DISABLED_GATE_PCT, "on": ENABLED_GATE_PCT}
    verdicts = {m: "PASS" if pct[m] <= gates[m] else "FAIL"
                for m in pct}
    self_ratio = None
    if "FAIL" in verdicts.values():
        # identical code re-run against itself: if the box cannot hold a
        # 2% self-ratio, the leg failed the weather, not the gate
        self_ratio = _self_ratio(n_calls, repeats)
        if self_ratio < 1.0 - DISABLED_GATE_PCT / 100.0:
            verdicts = {m: ("PASS-BASELINE-ALSO-FAILS" if v == "FAIL"
                            else v) for m, v in verdicts.items()}
    n_events, _snap = _validate_exports(max(64, n_calls // 4))

    rows = []
    for m in MODES:
        rows.append((f"obs/hotpath_us_per_call/{m}",
                     round(best[m] / n_calls * 1e6, 2),
                     f"calls_per_sec={n_calls / best[m]:.0f}"))
    rows.append(("obs/disabled_overhead_pct", round(pct["disabled"], 2),
                 f"need <= {DISABLED_GATE_PCT}%: {verdicts['disabled']}"))
    rows.append(("obs/enabled_overhead_pct", round(pct["on"], 2),
                 f"metrics+trace(stride={TRACE_STRIDE})"
                 f" need <= {ENABLED_GATE_PCT}%: {verdicts['on']}"))
    rows.append(("obs/export_validation", n_events,
                 "snapshot schema + p50/p99 + CHR + chrome trace: PASS"))
    overall = ("PASS" if set(verdicts.values()) == {"PASS"}
               else "PASS-BASELINE-ALSO-FAILS"
               if "FAIL" not in verdicts.values() else "FAIL")
    acceptance = {
        "disabled_overhead_pct": round(pct["disabled"], 3),
        "disabled_target_pct": DISABLED_GATE_PCT,
        "enabled_overhead_pct": round(pct["on"], 3),
        "enabled_target_pct": ENABLED_GATE_PCT,
        "trace_stride": TRACE_STRIDE,
        "export_validation": "PASS",
        "verdict": overall,
    }
    if self_ratio is not None:
        acceptance["baseline_self_ratio"] = round(self_ratio, 3)
    return rows, acceptance


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    # 2048 calls x ~45us keeps the timed region ~100ms: a 2% gate cannot
    # be judged on a ~10ms region where one scheduler preemption is 5%
    ap.add_argument("--n-calls", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=6,
                    help="multiple of 3 so each mode samples every "
                         "interleave position equally")
    args = ap.parse_args()
    n_calls = 128 if args.smoke else args.n_calls
    repeats = 3 if args.smoke else args.repeats
    rows, acceptance = run(n_calls, repeats)
    for row in rows:
        print(",".join(str(x) for x in row))
    # smoke runs export under a separate (gitignored) name so CI never
    # overwrites the committed full-run trajectory with tiny-n noise
    write_bench_json("smoke_obs_overhead" if args.smoke else "obs_overhead",
                     {"n_calls": n_calls, "repeats": repeats, "bs": BS,
                      "trace_stride": TRACE_STRIDE, "smoke": args.smoke},
                     rows, acceptance)


if __name__ == "__main__":
    main()
