"""Figure 11 analogue: throughput vs overflow ratio.

Inputs are crafted so a controlled fraction of lanes saturates during the
int32 ring aggregation; the fp32 fallback repairs exactly those lanes. We
verify correctness at every ratio and report wall time per call plus the
effective extra bytes the fallback path implies.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks._util import host_mesh, timeit
from repro.core import inc_agg
from repro import compat
from repro.core.inc_agg import IncAggConfig

L = 1 << 18


def run():
    rows = []
    mesh = host_mesh(model=2)
    n_dp = mesh.shape["data"]
    cfg = IncAggConfig(mode="netrpc", precision=8, fallback="always")

    def body(g):
        out, mask = inc_agg.all_reduce(g, ("data",), cfg)
        return out, mask

    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                              axis_names={"data"}, check_vma=False))
    rng = np.random.RandomState(0)
    for ratio in (0.0, 1e-5, 1e-4, 1e-3, 1e-2):
        g = rng.randn(L).astype(np.float32) * 0.1
        n_ovf = int(L * ratio)
        if n_ovf:
            g[:n_ovf] = 1e12          # quantizes to sentinel -> overflow
        gj = jnp.asarray(g)
        out, mask = f(gj)
        out = np.asarray(out)
        # correctness: every lane equals n_dp * g (fallback repaired lanes)
        assert np.allclose(out, n_dp * g, rtol=1e-3, atol=1e-4), ratio
        got_ratio = float(np.asarray(mask).mean())
        us = timeit(lambda x: f(x)[0], gj, warmup=1, iters=3)
        rows.append((f"f11/overflow_{ratio}", round(us, 1),
                     f"measured_ovf={got_ratio:.5f};repaired=ok"))
    return rows
