"""Figure 10 analogue: normalized throughput vs injected packet-loss rate.
The flip-bit protocol must stay exactly-once at every loss rate; throughput
degrades gracefully (goodput = useful packets / packets sent)."""
from __future__ import annotations

from repro.core.transport import run_flow


def run():
    rows = []
    base = None
    for loss in (0.0, 0.001, 0.01, 0.05, 0.1):
        res = run_flow(3000, loss, seed=42, w_max=64)
        assert res["duplicate_effects"] == {}, "exactly-once violated!"
        goodput = len(res["applied"]) / res["sent"] if res["sent"] else 0
        eff = len(res["applied"]) / (res["sent"] + res["retx"])
        if base is None:
            base = eff
        rows.append((f"f10/loss_{loss}", 0,
                     f"norm_throughput={eff / base:.3f};retx={res['retx']}"))
    return rows
