"""Figure 12 analogue: cache replacement policies — CHR and goodput.

Zipf-skewed key stream (with drifting hot set, which is what defeats FCFS
and PoN) into a capacity-limited switch partition; the paper's periodic
counting-based LRU should win on cache hit ratio and hence goodput.
"""
from __future__ import annotations

import numpy as np

from repro.core.inc_map import CACHE_POLICIES, ServerAgent, SwitchMemory


def run():
    rows = []
    rng = np.random.RandomState(7)
    n_keys, cap, n_batches, bs = 4096, 512, 60, 256
    # zipf stream with a hot-set drift every 20 batches
    streams = []
    for phase in range(3):
        perm = rng.permutation(n_keys)
        for _ in range(n_batches // 3):
            z = rng.zipf(1.2, bs) % n_keys
            streams.append(perm[z].astype(np.uint32))
    for policy in CACHE_POLICIES:
        srv = ServerAgent(SwitchMemory(4, 1024), gaid=1, n_slots=cap,
                          policy=policy, window=2048)
        truth = {}
        for batch in streams:
            vals = np.ones(bs, np.int64)
            for k in batch:
                truth[int(k)] = truth.get(int(k), 0) + 1
            srv.addto_batch(batch, vals)
        # correctness first
        for k, v in list(truth.items())[:200]:
            assert srv.read(k) == v, (policy, k)
        chr_ = srv.cache_hit_ratio
        goodput = srv.inc_bytes / max(srv.inc_bytes + srv.host_bytes, 1)
        rows.append((f"f12/{policy}", 0,
                     f"chr={chr_:.3f};inc_fraction={goodput:.3f}"))
    return rows
