"""Async-runtime sweep: p50/p99 call latency vs throughput per trigger.

The question PR 2's runtime must answer: does auto-drain (the scheduler
picking batch boundaries) keep the explicit-``drain()`` goodput of PR 1
while bounding tail latency for open-loop callers? Two sweeps over the
same monitoring-style Push stream (declared once as a typed schema
service; every async mode calls the generated stub's futures-first
``stub.Push(kvs=...)``):

  thr   open-loop: submit as fast as admission allows; calls/sec.
  lat   paced arrivals at ``LOAD_FRACTION`` of the measured explicit-drain
        capacity; per-call latency is arrival -> completion (completion
        timestamped by the resolving thread via IncFuture callbacks).

Modes:

  seq       one resolved future per call on a plain NetRPC — the batch=1
            inline pipeline baseline.
  explicit  NetRPC.submit + an explicit drain() every CHUNK calls (PR 1's
            caller-scheduled front, via the legacy compat shim).
  size      IncRuntime, size trigger only  (max_batch=CHUNK).
  time      IncRuntime, time trigger only  (max_delay=1ms).
  window    IncRuntime defaults: eager AIMD window trigger + size/time
            backstops (backpressure-coupled adaptive batching).
  abatch    bulk submission: ONE ``stub.Push.batch(reqs)`` call
            (IncRuntime.call_batch_async) queues the whole stream; the
            size trigger carves it into pipeline batches and admission
            backpressure throttles the submitter mid-list (thr only).

Acceptance (checked by the summary row): size or time auto-drain reaches
>= 80% of explicit-drain throughput, and its paced p99 stays below the
sequential baseline's p99 at the same offered load.

    PYTHONPATH=src python -m benchmarks.async_latency [--n 2048] [--smoke]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np

import repro.api as inc
from repro.api import DrainPolicy, IncRuntime, NetRPC

KEYS_PER_CALL = 16
CHUNK = 64                 # explicit-drain batch / size trigger
LOAD_FRACTION = 0.8        # paced offered load vs explicit capacity


@inc.service(app="AB-1")
class AsyncBench:
    @inc.rpc(request_msg="PushRequest")
    def Push(self, kvs: inc.Agg[inc.STRINTMap]) -> {"msg": inc.Plain}: ...


def _requests(n_calls: int, seed: int = 0) -> list[dict]:
    rng = np.random.RandomState(seed)
    return [{"kvs": {f"flow-{int(k)}": 1
                     for k in rng.zipf(1.3, KEYS_PER_CALL) % 2048}}
            for _ in range(n_calls)]


def _policy(mode: str) -> DrainPolicy:
    if mode in ("size", "abatch"):
        return DrainPolicy(max_batch=CHUNK, max_delay=5.0,
                           eager_window=False)
    if mode == "time":
        return DrainPolicy(max_batch=1 << 20, max_delay=0.001,
                           eager_window=False)
    return DrainPolicy(max_batch=CHUNK)     # window: eager AIMD defaults


def _fresh(mode: str):
    # "explicit_ctrl" is the committed-baseline control: the exact explicit
    # config run a second time, used to separate box weather from real
    # regressions when the acceptance gate fails
    if mode == "seq" or mode.startswith("explicit"):
        rt = NetRPC()
    else:
        rt = IncRuntime(policy=_policy(mode))
    return rt, rt.make_stub(AsyncBench, n_slots=8192)


def _close(rt) -> None:
    if isinstance(rt, IncRuntime):
        rt.close()


# -- open-loop throughput -----------------------------------------------------

def _warm(mode: str, rt, stub, req: dict) -> None:
    """One out-of-band call before the clock starts: spawns the scheduler
    thread (async modes) and touches every jit/kernel path, symmetrically
    across modes."""
    if mode.startswith("explicit"):
        rt.submit(stub.legacy, "Push", req)
        rt.drain()
    else:
        stub.Push(**req).result()


def _thr_once(mode: str, reqs: list[dict]) -> tuple[float, float]:
    import gc
    rt, stub = _fresh(mode)
    _warm(mode, rt, stub, reqs[0])
    gc.collect()
    gc.disable()     # same treatment for every mode (see agg_goodput)
    try:
        t0 = time.perf_counter()
        if mode == "seq":
            for r in reqs:
                stub.Push(**r).result()
        elif mode.startswith("explicit"):
            for i, r in enumerate(reqs):
                rt.submit(stub.legacy, "Push", r)
                if (i + 1) % CHUNK == 0:
                    rt.drain()
            rt.drain()
        elif mode == "abatch":
            for f in stub.Push.batch(reqs):
                f.result()
        else:
            futs = [stub.Push(**r) for r in reqs]
            for f in futs:
                f.result()
        dt = time.perf_counter() - t0
        mean_b = stub.channels["Push"].stats.mean_drained_batch
        return dt, mean_b
    finally:
        gc.enable()
        _close(rt)


def _thr(modes, reqs: list[dict], repeats: int) -> tuple[dict, dict]:
    """(mode -> (fastest calls/sec, mean drained batch),
        mode -> per-repeat wall times).

    Repeats are interleaved round-robin across modes so a slow patch on
    this (very jittery) container penalizes every mode alike instead of
    whichever one its measurement window landed on; the acceptance gate
    then compares *within-repeat* ratios (see run()).
    """
    best = {m: None for m in modes}
    samples = {m: [] for m in modes}
    for _ in range(repeats):
        for m in modes:
            dt, mean_b = _thr_once(m, reqs)
            samples[m].append(dt)
            if best[m] is None or dt < best[m][0]:
                best[m] = (dt, mean_b)
    return ({m: (len(reqs) / b[0], b[1]) for m, b in best.items()}, samples)


# -- paced latency ------------------------------------------------------------

def _lat(mode: str, reqs: list[dict], rate: float) -> np.ndarray:
    """Per-call arrival->completion latency (s) at ``rate`` arrivals/s."""
    import gc
    rt, stub = _fresh(mode)
    _warm(mode, rt, stub, reqs[0])
    lat = np.zeros(len(reqs))
    gc.collect()
    gc.disable()
    try:
        pending = []
        start = time.perf_counter()
        for i, r in enumerate(reqs):
            target = start + i / rate
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            if mode == "seq":
                stub.Push(**r).result()
                lat[i] = time.perf_counter() - target
            elif mode == "explicit":
                rt.submit(stub.legacy, "Push", r)
                pending.append((i, target))
                if len(pending) >= CHUNK:
                    rt.drain()
                    done = time.perf_counter()
                    for j, arr in pending:
                        lat[j] = done - arr
                    pending = []
            else:
                fut = stub.Push(**r)
                fut.add_done_callback(
                    lambda f, j=i, arr=target:
                    lat.__setitem__(j, time.perf_counter() - arr))
                pending.append(fut)
        if mode == "explicit" and pending:
            rt.drain()
            done = time.perf_counter()
            for j, arr in pending:
                lat[j] = done - arr
        elif mode != "explicit":
            for f in pending:
                f.result()
    finally:
        gc.enable()
        _close(rt)
    return lat


def run(n_calls: int = 2048, repeats: int = 5) -> list:
    reqs = _requests(n_calls)
    rows = []
    # warm the kernel/jit caches once so no mode pays first-call costs
    _thr_once("explicit", reqs[:4 * CHUNK])

    modes = ("seq", "explicit", "size", "time", "window", "abatch")
    thr, samples = _thr(modes, reqs, repeats)
    cps = {m: thr[m][0] for m in modes}
    for mode in modes:
        c, mean_b = thr[mode]
        rows.append((f"t_async/thr/{mode}", round(1e6 / c, 1),
                     f"calls_per_sec={c:.0f}"
                     f" speedup_vs_seq={c / cps['seq']:.2f}x"
                     f" mean_drained_batch={mean_b:.1f}"))

    rate = LOAD_FRACTION * cps["explicit"]
    p99 = {}
    for mode in ("seq", "explicit", "size", "time", "window"):
        lat = _lat(mode, reqs, rate) * 1e6
        p99[mode] = float(np.percentile(lat, 99))
        rows.append((f"t_async/lat/{mode}@{LOAD_FRACTION:.1f}x",
                     round(float(np.percentile(lat, 50)), 1),
                     f"p99_us={p99[mode]:.0f}"
                     f" offered_cps={rate:.0f}"))

    # a single trigger config must meet BOTH criteria (mixing the best
    # throughput of one mode with the best p99 of another would certify a
    # configuration that does not exist). The throughput ratio is the
    # median of WITHIN-repeat ratios: comparing each mode's fastest-of-N
    # instead would let one golden scheduling window for one mode decide
    # the gate on this jittery container.
    ratio = {m: float(np.median([e / a for e, a in
                                 zip(samples["explicit"], samples[m])]))
             for m in ("size", "time", "abatch")}
    passing = [m for m in ("size", "time")
               if ratio[m] >= 0.8 and p99[m] < p99["seq"]]
    best = max(("size", "time"), key=lambda m: ratio[m])
    verdict = "PASS" if passing else "FAIL"
    baseline_note = ""
    if not passing and all(ratio[m] < 0.8 for m in ("size", "time")):
        # ROADMAP caveat: the throughput leg of this gate is box-weather
        # sensitive. Before reporting a bare FAIL, rerun the committed
        # baseline config (explicit drain) against itself, interleaved, in
        # this same session: when identical code + config cannot hold the
        # 0.8 ratio against its own replay, the box — not the change —
        # failed the leg.
        _, ctrl_samples = _thr(("explicit", "explicit_ctrl"), reqs,
                               repeats)
        ctrl_ratio = float(np.median(
            [a / b for a, b in zip(ctrl_samples["explicit"],
                                   ctrl_samples["explicit_ctrl"])]))
        stable = (min(ctrl_ratio, 1.0 / ctrl_ratio) if ctrl_ratio > 0
                  else 0.0)
        baseline_note = f" baseline_self_ratio={ctrl_ratio:.2f}"
        if stable < 0.8:
            verdict = "PASS-BASELINE-ALSO-FAILS"
    rows.append(("t_async/acceptance", 0,
                 f"modes_meeting_both={passing or 'none'}"
                 f" ({verdict})"
                 f" median_auto_vs_explicit={best}:{ratio[best]:.2f}"
                 f" batch_async_vs_explicit={ratio['abatch']:.2f}"
                 f"{baseline_note}"))
    acceptance = {
        "verdict": verdict,
        "modes_meeting_both": list(passing),
        "median_auto_vs_explicit": {m: round(r, 3)
                                    for m, r in ratio.items()},
        "p99_us": {m: round(v, 1) for m, v in p99.items()},
    }
    return rows, acceptance


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    args = ap.parse_args()
    n = 4 * CHUNK if args.smoke else args.n
    repeats = 1 if args.smoke else args.repeats
    rows, acceptance = run(n, repeats=repeats)
    for row in rows:
        print(",".join(str(x) for x in row))
    from benchmarks._util import write_bench_json
    # smoke runs export under a separate (gitignored) name so CI never
    # overwrites the committed full-run trajectory with tiny-n noise
    write_bench_json("smoke_async_latency" if args.smoke
                     else "async_latency",
                     {"n_calls": n, "repeats": repeats,
                      "load_fraction": LOAD_FRACTION, "chunk": CHUNK,
                      "smoke": args.smoke},
                     rows, acceptance)


if __name__ == "__main__":
    main()
