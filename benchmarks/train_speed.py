"""Figure 6 analogue: training speed per INC mode.

xla-psum plays BytePS (pure software all-reduce); netrpc is the
paper-faithful INC path; netrpc-opt the beyond-paper wire format. Reduced
configs on host devices; the derived column also reports modeled per-rank
wire bytes per step (the hardware-independent signal — on one CPU core the
wall-clock ordering is not TPU-representative).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import host_mesh, timeit
from repro.configs.base import ShapeConfig, get_arch
from repro.core.inc_agg import IncAggConfig
from repro.data import pipeline
from repro.launch import steps
from repro.models import api
from repro.optim.adamw import AdamWConfig


def run():
    rows = []
    mesh = host_mesh(model=2)
    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeConfig("b", seq_len=128, global_batch=8, kind="train")
    n_params = api.count_params(cfg)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, batch=8, seq_len=128,
                               kind="uniform")
    batch = pipeline.make_batch(dcfg, 0)
    n_dp = mesh.shape["data"]
    for mode in ("xla-psum", "fp32-ring", "netrpc", "netrpc-opt"):
        prog = steps.build_train_step(
            cfg, shape, mesh, inc=IncAggConfig(mode=mode, precision=8),
            opt_cfg=AdamWConfig(), n_micro=1, donate=False)
        params, opt = steps.init_state(prog, cfg)
        us = timeit(lambda p, o, b: prog.fn(p, o, b, jnp.int32(1)),
                    params, opt, batch, warmup=1, iters=3)
        wire = {"xla-psum": 4, "fp32-ring": 4, "netrpc": 8,
                "netrpc-opt": 2}[mode] * n_params * (n_dp - 1) / n_dp
        rows.append((f"f6/train_step/{mode}", round(us, 1),
                     f"steps_per_s={1e6 / us:.2f};wire_bytes={wire:.0f}"))
    return rows
