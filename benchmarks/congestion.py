"""Figures 8-9 analogue: ECN-AIMD congestion control — fairness of two
concurrent flows sharing one switch queue, and packet-loss reduction with
the controller on vs off."""
from __future__ import annotations

import random

from repro.core.transport import (AimdState, ClientFlow, FlipBitSwitch,
                                  LossyLink, flip_of)


def two_flows(n_packets=2000, ecn_on=True, seed=0):
    sw = FlipBitSwitch(w_max=64, queue_capacity=48, ecn_threshold=32)
    flows = [ClientFlow(i, n_packets, w_max=64,
                        rng=random.Random(seed + i)) for i in range(2)]
    if not ecn_on:
        for f in flows:
            f.aimd = AimdState(cw=64, additive=0, multiplicative=1.0,
                               cw_max=64)      # fixed max window
    drops = 0
    rounds = 0
    done_at = [None, None]
    while not all(f.done for f in flows):
        rounds += 1
        for f in flows:
            if f.done:
                continue
            batch = f.sendable() or f.retransmissions()
            for pkt in batch:
                # tail drop when the shared queue is full
                if sw.queue_len >= sw.queue_capacity:
                    drops += 1
                    continue
                sw.ingress(pkt)
                f.on_ack(pkt.seq, pkt.ecn)
        sw.drain(56)      # shared service rate
        if rounds > 200000:
            break
    for i, f in enumerate(flows):
        done_at[i] = f.sent_total + f.retx_total
    return drops, rounds, [f.aimd.cw for f in flows], done_at


def run():
    rows = []
    d_on, r_on, cws, sent_on = two_flows(ecn_on=True)
    d_off, r_off, _, sent_off = two_flows(ecn_on=False)
    total_on = sum(sent_on)
    fairness = min(sent_on) / max(sent_on)
    rows.append(("f8/fairness_jain_min_over_max", 0, round(fairness, 3)))
    rows.append(("f8/final_cw_flow0", 0, cws[0]))
    rows.append(("f8/final_cw_flow1", 0, cws[1]))
    loss_on = d_on / max(total_on + d_on, 1)
    loss_off = d_off / max(sum(sent_off) + d_off, 1)
    rows.append(("f9/loss_rate_ecn_on", 0, round(loss_on, 4)))
    rows.append(("f9/loss_rate_ecn_off", 0, round(loss_off, 4)))
    red = 1 - loss_on / max(loss_off, 1e-9)
    rows.append(("f9/loss_reduction_pct", 0, round(100 * red, 1)))
    return rows
