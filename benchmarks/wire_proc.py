"""Multi-process wire plane vs in-process plane: GPV addto throughput.

ISSUE 10's acceptance: putting the register file in a real ``switchd``
subprocess (length-prefixed frames over a Unix socket, sliding window +
AIMD, per-seq RTO) must cost no more than ~20% of in-process GPV addto
throughput at the 64k-element size (ratio >= 0.8). Both legs run the
identical op stream against the identical ``SwitchMemory`` geometry —
the only difference is the process boundary. The ratio can exceed 1.0:
clients ship contiguous GPV ranges as a two-int ``dense`` meta (no
8-byte-per-slot address array) and the daemon applies them with the
slice-arithmetic ``addto_dense`` verb, while the in-process leg pays
the general scatter path — plus the wire leg overlaps client-side
serialization with daemon-side applies across two processes.

Correctness is asserted before any timing is trusted: a chaos probe
(5% loss / dup / reorder via ``FaultProxy`` + one mid-run SIGTERM +
respawn-from-spool of the daemon) must produce element-exact registers
with ``duplicate_effects == {}`` — the exactly-once contract is a hard
gate, never box weather.

The throughput gate *is* box-weather sensitive (this container jitters).
Before reporting FAIL, the in-process baseline is replayed against
itself; when identical code + config cannot hold the 0.8 ratio against
its own replay, the verdict is PASS-BASELINE-ALSO-FAILS rather than
FAIL.

    PYTHONPATH=src python -m benchmarks.wire_proc [--smoke] [--csv]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np

from repro.core.inc_map import SwitchMemory
from repro.net import FaultProxy, FaultSpec, RemoteSwitchMemory, \
    WireTransport

SIZES = (1 << 12, 1 << 14, 1 << 16)
GATE_N = 1 << 16
GATE_RATIO = 0.8                  # wire within ~20% of in-process
SEGMENTS = 8
SEG_SLOTS = 16_384                # 8 x 16384 = 128k slots: fits 64k GPV


def _spawn_switchd(uds: str, spool: str | None = None,
                   track_effects: bool = False) -> subprocess.Popen:
    import repro
    env = dict(os.environ)
    src = os.path.dirname(list(repro.__path__)[0])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.switchd", "--uds", uds,
           "--segments", str(SEGMENTS), "--slots", str(SEG_SLOTS)]
    if spool:
        cmd += ["--state-spool", spool]
    if track_effects:
        cmd.append("--track-effects")
    p = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True, env=env)
    line = p.stdout.readline()
    if "SWITCHD READY" not in line:
        p.kill()
        raise RuntimeError(f"switchd failed to start: {line!r}")
    return p


def _stop_switchd(p: subprocess.Popen) -> None:
    if p.poll() is None:
        p.send_signal(signal.SIGTERM)
        try:
            p.wait(timeout=15)
        except subprocess.TimeoutExpired:
            p.kill()


def _stream(mem, n: int, ops: int, seed: int) -> np.ndarray:
    """The shared workload: ``ops`` GPV addtos of ``n`` elements;
    returns the expected accumulation."""
    phys = np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(seed)
    expect = np.zeros(n, dtype=np.int64)
    for _ in range(ops):
        vals = rng.integers(-999, 999, size=n).astype(np.int32)
        mem.addto(phys, vals)
        expect += vals
    return expect


def _time_local(n: int, ops: int) -> float:
    mem = SwitchMemory(n_segments=SEGMENTS, seg_slots=SEG_SLOTS)
    assert mem.reserve(1, n)
    _stream(mem, n, 2, seed=0)                     # warmup
    t0 = time.perf_counter()
    _stream(mem, n, ops, seed=1)
    return time.perf_counter() - t0


def _time_wire(n: int, ops: int) -> float:
    uds = f"/tmp/repro_wire_proc_{os.getpid()}.sock"
    daemon = _spawn_switchd(uds)
    t = WireTransport(uds, flow_id=1, call_timeout=60.0)
    mem = RemoteSwitchMemory(t, n_segments=SEGMENTS, seg_slots=SEG_SLOTS)
    try:
        assert mem.reserve(1, n)
        _stream(mem, n, 2, seed=0)
        t.barrier()                                # warmup incl. drain
        t0 = time.perf_counter()
        _stream(mem, n, ops, seed=1)
        t.barrier()                                # ops ACKed, not queued
        return time.perf_counter() - t0
    finally:
        t.close()
        _stop_switchd(daemon)
        if os.path.exists(uds):
            os.unlink(uds)


def _chaos_probe(n: int = 512, ops: int = 20) -> dict:
    """Exactly-once across 5% loss AND one daemon restart-from-spool.
    Raises on any divergence — correctness is not box weather."""
    uds = f"/tmp/repro_wire_chaos_{os.getpid()}.sock"
    spool = f"/tmp/repro_wire_chaos_{os.getpid()}.pkl"
    for path in (uds, spool):
        if os.path.exists(path):
            os.unlink(path)
    daemon = _spawn_switchd(uds, spool=spool, track_effects=True)
    px = FaultProxy(uds, FaultSpec(seed=13, loss=0.05, dup=0.025,
                                   reorder=0.025)).start()
    # unreachable_after must exceed the daemon's respawn time (a cold
    # python + jax import), or the client degrades to its local plane
    # mid-probe and the state legitimately forks
    t = WireTransport(px.address, flow_id=1, w_max=8, rto_base=0.02,
                      call_timeout=60.0, unreachable_after=120.0)
    mem = RemoteSwitchMemory(t, n_segments=SEGMENTS, seg_slots=SEG_SLOTS)
    try:
        assert mem.reserve(1, n)
        phys = np.arange(n, dtype=np.int64)
        expect = _stream(mem, n, ops, seed=2)
        t.barrier()
        _stop_switchd(daemon)                      # SIGTERM -> spool
        daemon = _spawn_switchd(uds, spool=spool, track_effects=True)
        expect += _stream(mem, n, ops, seed=3)
        got = mem.get(phys).astype(np.int64)
        if not np.array_equal(got, expect):
            raise AssertionError(
                f"wire state diverged after restart: "
                f"{int(np.abs(got - expect).sum())} absolute error")
        stats = t.ctrl("stats")
        if stats["duplicate_effects"]:
            raise AssertionError(
                f"double-applied effects: {stats['duplicate_effects']}")
        rep = t.report()
        return {"exact": True, "restarts": 1, "retx": rep["retx"],
                "reconnects": rep["reconnects"]}
    finally:
        t.close()
        px.stop()
        _stop_switchd(daemon)
        for path in (uds, spool):
            if os.path.exists(path):
                os.unlink(path)


def run(sizes=SIZES, repeats: int = 3) -> tuple[list, dict]:
    rows = []
    probe = _chaos_probe()
    rows.append(("t_wire_proc/chaos", 0,
                 f"exact={probe['exact']} restarts={probe['restarts']}"
                 f" retx={probe['retx']} reconnects={probe['reconnects']}"))
    gate_samples = []
    for n in sizes:
        ops = max(4, min(24, (1 << 21) // n))
        ratios = []
        t_local = t_wire = None
        for _ in range(repeats):
            dl = _time_local(n, ops)
            dw = _time_wire(n, ops)
            ratios.append(dl / dw)                 # within-repeat ratio
            t_local = dl if t_local is None else min(t_local, dl)
            t_wire = dw if t_wire is None else min(t_wire, dw)
        for leg, dt in (("local", t_local), ("wire", t_wire)):
            rows.append((f"t_wire_proc/{leg}/n{n}",
                         round(dt / ops * 1e6, 1),
                         f"elems_per_sec={ops * n / dt:.0f}"))
        ratio = float(np.median(ratios))
        rows.append((f"t_wire_proc/ratio/n{n}", 0,
                     f"wire_vs_local={ratio:.2f}x"))
        if n == GATE_N:
            gate_samples = ratios
    acceptance = {"chaos_exact": True}
    if gate_samples:
        gate = float(np.median(gate_samples))
        verdict = "PASS" if gate >= GATE_RATIO else "FAIL"
        baseline_note = ""
        if verdict == "FAIL":
            # box-weather guard: identical in-process code replayed
            # against itself; if THAT can't hold 0.8, the box failed
            ops = max(4, min(24, (1 << 21) // GATE_N))
            selfs = []
            for _ in range(repeats):
                a = _time_local(GATE_N, ops)
                b = _time_local(GATE_N, ops)
                selfs.append(a / b)
            ctrl = float(np.median(selfs))
            stable = min(ctrl, 1.0 / ctrl) if ctrl > 0 else 0.0
            baseline_note = f" baseline_self_ratio={ctrl:.2f}"
            if stable < GATE_RATIO:
                verdict = "PASS-BASELINE-ALSO-FAILS"
        rows.append(("t_wire_proc/acceptance", 0,
                     f"wire_vs_local@{GATE_N}={gate:.2f}x"
                     f" (need >= {GATE_RATIO}: {verdict}){baseline_note}"))
        acceptance.update({"wire_vs_local": round(gate, 2),
                           "target": GATE_RATIO, "verdict": verdict})
    return rows, acceptance


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (chaos probe at full strength, "
                         "timing numbers not asserted)")
    ap.add_argument("--csv", action="store_true",
                    help="append the rows to benchmarks/results.csv")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    sizes = (1 << 10, 1 << 12) if args.smoke else SIZES
    repeats = 1 if args.smoke else args.repeats
    rows, acceptance = run(sizes, repeats=repeats)
    lines = [",".join(str(x) for x in row) for row in rows]
    for ln in lines:
        print(ln)
    from benchmarks._util import write_bench_json
    write_bench_json("smoke_wire_proc" if args.smoke else "wire_proc",
                     {"sizes": list(sizes), "repeats": repeats,
                      "smoke": args.smoke},
                     rows, acceptance)
    if args.csv:
        from pathlib import Path
        out = Path(__file__).resolve().parent / "results.csv"
        with out.open("a") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
