"""Device-resident GPV sweep: fused Pallas data plane vs host GPV path.

ISSUE 6's question: what does keeping the register file on device buy the
GPV tensor path?  Both legs run the SAME pipeline, schema layer, and
vectorized INC map — the only difference is ``device=`` on the Agg/Get
annotations: the host leg quantizes with numpy and scatter-adds into a
numpy register file; the device leg keeps the segment as a jax int32
array and lowers quantize -> saturating addto (and gather -> dequantize
on the reply) through ONE fused Pallas kernel each, with the reply coming
back as a device-resident fp32 jax array.

Correctness is the primary export on this container: the probe asserts
the device leg is element-exact vs the host leg (identical int32
registers; replies equal under the shared reciprocal-dequant formula)
before any timing is trusted.  Timings are honest either way, but the
>=5x acceptance gate only arms when a compiled Pallas backend (TPU/GPU)
is present — in interpret mode (CPU) the kernels run under the Pallas
interpreter, which benchmarks the lane's correctness, not its speed, so
the acceptance row reports "correctness-only PASS" instead.

    PYTHONPATH=src python -m benchmarks.device_path [--smoke] [--csv]
"""
from __future__ import annotations

if __package__ in (None, ""):            # executed as a bare script
    import sys
    from pathlib import Path
    _root = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))

import time

import numpy as np

import repro.api as inc
from repro.kernels.backend import accelerator_present, pallas_mode

SIZES = (1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18)
GATE_N = 1 << 18        # the acceptance-row payload size (256k)
GATE_X = 5.0            # ISSUE 6: device >= 5x host GPV at 256k (compiled)


@inc.service(app="DEVP-dev", name="DeviceGrad")
class DeviceGrad:
    @inc.rpc(request_msg="NewGrad", reply_msg="AgtrGrad")
    def Update(self, tensor: inc.Agg[inc.FPArray](
            precision=6, clear="copy", device=True)
            ) -> {"tensor": inc.Get[inc.FPArray]}: ...


@inc.service(app="DEVP-host", name="HostGrad")
class HostGrad:
    @inc.rpc(request_msg="NewGrad", reply_msg="AgtrGrad")
    def Update(self, tensor: inc.Agg[inc.FPArray](
            precision=6, clear="copy")
            ) -> {"tensor": inc.Get[inc.FPArray]}: ...


def _fresh(device: bool, n: int):
    rt = inc.NetRPC()
    return rt.make_stub(DeviceGrad if device else HostGrad, n_slots=n)


def _probe(n: int = 4096) -> None:
    """Device leg must match the host leg element-exactly before timings
    mean anything: identical int32 register contents, and replies equal
    under the shared reciprocal dequantize (raw * (1/float32(scale)))."""
    g = (np.random.RandomState(0).randn(n) * 3).astype(np.float32)
    out = {}
    for device in (False, True):
        stub = _fresh(device, n)
        stub.Update(tensor=g).result()          # grant storm
        out[device] = np.asarray(stub.Update(tensor=g).result()["tensor"])
    # the shared quantize oracle (f32 product, round-half-even): both legs
    # must hold exactly these registers after the clear="copy" round
    raw = np.rint(g * np.float32(10.0 ** 6)).astype(np.int64)
    assert np.array_equal(out[False], raw / (10 ** 6)), \
        "host leg diverged from the quantize oracle"
    inv = np.float32(1.0) / np.float32(10.0 ** 6)
    assert np.array_equal(out[True], raw.astype(np.float32) * inv), \
        "device leg diverged from the quantize oracle (fp32 reciprocal)"


def _time_leg(device: bool, n: int, iters: int, repeats: int) -> float:
    """Fastest mean seconds/call of a steady-state Update (addTo + Get +
    clear) on a fresh stub per replay; the grant-storm first call is
    off-clock warmup."""
    import gc
    import jax
    g = np.random.RandomState(1).randn(n).astype(np.float32)
    best = None
    for _ in range(repeats):
        stub = _fresh(device, n)
        jax.block_until_ready(stub.Update(tensor=g).result()["tensor"])
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(
                    stub.Update(tensor=g).result()["tensor"])
            dt = (time.perf_counter() - t0) / iters
        finally:
            gc.enable()
        best = dt if best is None else min(best, dt)
    return best


def run(sizes=SIZES, repeats: int = 3) -> tuple[list, dict]:
    _probe()
    mode = pallas_mode()
    rows = [("t_device/pallas_mode", 0, f"mode={mode}")]
    gate = None
    for n in sizes:
        iters = max(2, min(12, (1 << 19) // n))
        t_host = t_dev = None
        for _ in range(repeats):      # interleave so jitter hits both alike
            h = _time_leg(False, n, iters, 1)
            d = _time_leg(True, n, iters, 1)
            t_host = h if t_host is None else min(t_host, h)
            t_dev = d if t_dev is None else min(t_dev, d)
        ratio = t_host / t_dev
        if n == GATE_N:
            gate = ratio
        for leg, dt in (("host", t_host), ("device", t_dev)):
            rows.append((f"t_device/{leg}/n{n}", round(dt * 1e6, 1),
                         f"calls_per_sec={1.0 / dt:.1f}"
                         f" elems_per_sec={n / dt:.0f}"))
        rows.append((f"t_device/speedup/n{n}", 0,
                     f"device_vs_host={ratio:.2f}x"))
    acceptance = {"pallas_mode": mode, "probe": "exact"}
    if gate is not None:
        if accelerator_present():
            verdict = "PASS" if gate >= GATE_X else "FAIL"
            note = (f"device_vs_host@{GATE_N}={gate:.2f}x "
                    f"(need >= {GATE_X:.0f}x compiled: {verdict})")
            acceptance.update({"device_vs_host": round(gate, 2),
                               "target": GATE_X, "verdict": verdict})
        else:
            # interpret mode measures the Pallas interpreter, not the
            # lane: the gate is correctness-only until an accelerator
            # shows up (tests/test_device_path.py xfail-not-skip marks
            # the compiled lane for the same reason)
            verdict = "correctness-only PASS"
            note = (f"device_vs_host@{GATE_N}={gate:.2f}x interpret-mode "
                    f"(no accelerator; gate = {verdict})")
            acceptance.update({"device_vs_host": round(gate, 2),
                               "target": GATE_X, "verdict": verdict})
        rows.append(("t_device/acceptance", 0, note))
    return rows, acceptance


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (correct plumbing, noisy numbers)")
    ap.add_argument("--csv", action="store_true",
                    help="append the rows to benchmarks/results.csv")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    sizes = (1 << 10, 1 << 12) if args.smoke else SIZES
    repeats = 1 if args.smoke else args.repeats
    rows, acceptance = run(sizes, repeats=repeats)
    lines = [",".join(str(x) for x in row) for row in rows]
    for ln in lines:
        print(ln)
    from benchmarks._util import write_bench_json
    # smoke runs export under a separate (gitignored) name so CI never
    # overwrites the committed full-run trajectory with tiny-n noise
    write_bench_json("smoke_device_path" if args.smoke else "device_path",
                     {"sizes": list(sizes), "repeats": repeats,
                      "smoke": args.smoke},
                     rows, acceptance)
    if args.csv:
        from pathlib import Path
        out = Path(__file__).resolve().parent / "results.csv"
        with out.open("a") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
