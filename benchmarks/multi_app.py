"""Table 7 analogue: concurrent applications on one shared data plane.

1APP / 4APP / 4APPx5: SyncAgtr + AsyncAgtr goodput and KeyValue/Agreement
latency as the number of co-resident channels grows. The claim to
reproduce: bandwidth-heavy apps keep their combined goodput; small apps'
latency rises only mildly.
"""
from __future__ import annotations

import time

import numpy as np

import repro.api as inc
from repro.core.agreement import CntFwd
from repro.core.channel import Controller
from repro.core.netfilter import NetFilter


def mk_apps(controller, n_per_type, tag):
    apps = {"sync": [], "async": [], "kv": [], "agree": []}
    for i in range(n_per_type):
        s = controller.register(NetFilter.from_dict(
            {"AppName": f"sync-{tag}-{i}", "addTo": "R.t", "get": "Y.t",
             "clear": "copy"}), n_slots=4096)
        a = controller.register(NetFilter.from_dict(
            {"AppName": f"async-{tag}-{i}", "addTo": "R.kvs"}),
            n_slots=4096)
        k = controller.register(NetFilter.from_dict(
            {"AppName": f"kv-{tag}-{i}", "get": "Y.kvs"}), n_slots=2048)
        g = controller.register(NetFilter.from_dict(
            {"AppName": f"agree-{tag}-{i}",
             "CntFwd": {"to": "SRC", "threshold": 2, "key": "b"}}),
            n_slots=256)
        apps["sync"].append(s)
        apps["async"].append(a)
        apps["kv"].append(k)
        apps["agree"].append(g)
    return apps


def drive(apps, n_rounds=40):
    rng = np.random.RandomState(0)
    t_sync = t_async = 0.0
    bytes_sync = bytes_async = 0
    lat_kv = []
    lat_ag = []
    for r in range(n_rounds):
        for ch in apps["sync"]:
            k = np.arange(256, dtype=np.uint32)
            v = rng.randint(1, 50, 256)
            t0 = time.perf_counter()
            ch.server.addto_batch(k, v)
            t_sync += time.perf_counter() - t0
            bytes_sync += 256 * 8
        for ch in apps["async"]:
            k = (rng.zipf(1.3, 256) % 4096).astype(np.uint32)
            v = rng.randint(1, 50, 256)
            t0 = time.perf_counter()
            ch.server.addto_batch(k, v)
            t_async += time.perf_counter() - t0
            bytes_async += 256 * 8
        for ch in apps["kv"]:
            t0 = time.perf_counter()
            ch.server.read(rng.randint(0, 2048))
            lat_kv.append(time.perf_counter() - t0)
        for ch in apps["agree"]:
            cf = CntFwd(server=ch.server, threshold=2)
            t0 = time.perf_counter()
            cf.offer(r)
            lat_ag.append(time.perf_counter() - t0)
    return (bytes_sync / max(t_sync, 1e-9), bytes_async / max(t_async, 1e-9),
            np.mean(lat_kv) * 1e6 if lat_kv else 0.0,
            np.mean(lat_ag) * 1e6 if lat_ag else 0.0)


def mk_services(n_apps: int) -> list:
    """One typed schema class per co-resident app (distinct AppName -> its
    own channel); the class body is re-evaluated per app, so the schema
    layer parameterizes cleanly."""
    svcs = []
    for i in range(n_apps):
        @inc.service(app=f"coal-{i}", name=f"Mon{i}")
        class Mon:
            @inc.rpc(request_msg="R")
            def Push(self, kvs: inc.Agg[inc.STRINTMap]
                     ) -> {"msg": inc.Plain}: ...
        svcs.append(Mon)
    return svcs


def run_coalesced(n_apps: int = 4, n_clients: int = 4, n_rounds: int = 64
                  ) -> list:
    """Shared-plane micro-batching (the multi-application plane of Fig. 12):
    each round, every client of every app issues one call. per-call runs
    them sequentially; submit/drain coalesces each app's clients into one
    pipeline batch per channel per round."""
    rng = np.random.RandomState(0)
    reqs = [[[{"kvs": {f"f-{int(k)}": 1 for k in rng.zipf(1.3, 16) % 512}}
              for _ in range(n_clients)] for _ in range(n_apps)]
            for _ in range(n_rounds)]

    def setup():
        rt = inc.NetRPC()
        stubs = [[rt.make_stub(svc, n_slots=1024) for _ in range(n_clients)]
                 for svc in mk_services(n_apps)]
        return rt, stubs

    rt, stubs = setup()
    t0 = time.perf_counter()
    for rnd in reqs:
        for a, app_reqs in enumerate(rnd):
            for c, r in enumerate(app_reqs):
                stubs[a][c].Push(**r).result()
    t_seq = time.perf_counter() - t0

    rt, stubs = setup()
    t0 = time.perf_counter()
    for rnd in reqs:
        for a, app_reqs in enumerate(rnd):
            for c, r in enumerate(app_reqs):
                rt.submit(stubs[a][c].legacy, "Push", r)
        rt.drain()
    t_coal = time.perf_counter() - t0
    ch = stubs[0][0].channels["Push"]
    n_calls = n_apps * n_clients * n_rounds
    # mean_drained_batch counts only runtime-coalesced passes, so warm-up
    # or interleaved N=1 Stub.call traffic can no longer dilute the
    # coalescing efficiency this row reports
    return [
        ("t7/coalesced/per_call_us", round(t_seq / n_calls * 1e6, 1),
         f"calls_per_sec={n_calls / t_seq:.0f}"),
        ("t7/coalesced/drain_us", round(t_coal / n_calls * 1e6, 1),
         f"calls_per_sec={n_calls / t_coal:.0f}"
         f" speedup={t_seq / t_coal:.2f}x"
         f" mean_drained_batch={ch.stats.mean_drained_batch:.1f}"),
    ]


def run():
    rows = []
    for label, n in (("1app", 1), ("4app", 1), ("4appx5", 5)):
        c = Controller(Controller().switch.__class__(64, 40_000))
        apps = mk_apps(c, n, label)
        if label == "1app":      # only the sync app active
            apps = {"sync": apps["sync"], "async": [], "kv": [],
                    "agree": []}
        gs, ga, lkv, lag = drive(apps)
        rows.append((f"t7/{label}/sync_goodput_MBps", 0,
                     round(gs / 1e6, 2)))
        rows.append((f"t7/{label}/async_goodput_MBps", 0,
                     round(ga / 1e6, 2)))
        rows.append((f"t7/{label}/kv_delay_us", round(lkv, 1),
                     "-" if lkv == 0 else ""))
        rows.append((f"t7/{label}/agree_delay_us", round(lag, 1),
                     "-" if lag == 0 else ""))
    rows.extend(run_coalesced())
    return rows
