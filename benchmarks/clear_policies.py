"""Table 6 analogue: Map.clear policies — latency / memory / throughput.

Latency proxy: wall time of one read_and_clear round trip. Memory: the
policy's multiplier. Throughput proxy: addto rounds per second sustained
across clears, including lazy's overflow-forced fallback resets at
controlled overflow ratios (the lazy 0%/1%/10% rows).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.clear_policy import make_clear_policy
from repro.kernels.constants import SAT_MAX

N = 1 << 16


def run():
    rows = []
    rng = np.random.RandomState(3)
    for policy in ("copy", "shadow", "lazy"):
        pol = make_clear_policy(policy, N)
        q = jnp.asarray(rng.randint(-1000, 1000, N).astype(np.int32))
        t0 = time.perf_counter()
        rounds = 30
        for _ in range(rounds):
            pol.addto(q)
            pol.read_and_clear()
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append((f"t6/{policy}", round(us, 1),
                     f"mem_x={pol.stats.memory_multiplier};"
                     f"hops={pol.stats.roundtrip_hops}"))

    # batched reply-path fold: a drained batch of B updates lands in ONE
    # fused sat_add_batch pass instead of B addto dispatches. Measured at
    # a register-segment size (where per-dispatch overhead dominates, the
    # regime the RPC reply path lives in), not the Table-6 tensor size.
    B, n_seg = 16, 4096
    for policy in ("copy", "shadow", "lazy"):
        qs = [jnp.asarray(rng.randint(-1000, 1000, n_seg).astype(np.int32))
              for _ in range(B)]
        pol = make_clear_policy(policy, n_seg)
        pol.addto_batch(qs)                  # warm the fold jit
        pol.read_and_clear()
        t0 = time.perf_counter()
        rounds = 20
        for _ in range(rounds):
            pol.addto_batch(qs)
            pol.read_and_clear()
        us = (time.perf_counter() - t0) / (rounds * B) * 1e6
        pol2 = make_clear_policy(policy, n_seg)
        pol2.addto(qs[0])
        pol2.read_and_clear()
        t0 = time.perf_counter()
        for _ in range(rounds):
            for q in qs:
                pol2.addto(q)
            pol2.read_and_clear()
        us_seq = (time.perf_counter() - t0) / (rounds * B) * 1e6
        rows.append((f"t6/{policy}_batch{B}_n{n_seg}", round(us, 1),
                     f"per_call_us_sequential={us_seq:.1f};"
                     f"speedup={us_seq / max(us, 1e-9):.2f}x"))

    # lazy under overflow pressure
    for ratio in (0.0, 0.01, 0.1):
        pol = make_clear_policy("lazy", N)
        base = rng.randint(-1000, 1000, N).astype(np.int64)
        n_hot = int(N * ratio)
        if n_hot:
            base[:n_hot] = SAT_MAX // 2 + 1     # overflows on 2nd addto
        q = jnp.asarray(base.astype(np.int32))
        t0 = time.perf_counter()
        rounds = 20
        for _ in range(rounds):
            pol.addto(q)
            pol.read_and_clear()
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append((f"t6/lazy_ovf_{ratio}", round(us, 1),
                     f"fallback_resets={pol.stats.fallback_resets}"))
    return rows
