"""Table 6 analogue: Map.clear policies — latency / memory / throughput.

Latency proxy: wall time of one read_and_clear round trip. Memory: the
policy's multiplier. Throughput proxy: addto rounds per second sustained
across clears, including lazy's overflow-forced fallback resets at
controlled overflow ratios (the lazy 0%/1%/10% rows).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.clear_policy import make_clear_policy
from repro.kernels.constants import SAT_MAX

N = 1 << 16


def run():
    rows = []
    rng = np.random.RandomState(3)
    for policy in ("copy", "shadow", "lazy"):
        pol = make_clear_policy(policy, N)
        q = jnp.asarray(rng.randint(-1000, 1000, N).astype(np.int32))
        t0 = time.perf_counter()
        rounds = 30
        for _ in range(rounds):
            pol.addto(q)
            pol.read_and_clear()
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append((f"t6/{policy}", round(us, 1),
                     f"mem_x={pol.stats.memory_multiplier};"
                     f"hops={pol.stats.roundtrip_hops}"))

    # lazy under overflow pressure
    for ratio in (0.0, 0.01, 0.1):
        pol = make_clear_policy("lazy", N)
        base = rng.randint(-1000, 1000, N).astype(np.int64)
        n_hot = int(N * ratio)
        if n_hot:
            base[:n_hot] = SAT_MAX // 2 + 1     # overflows on 2nd addto
        q = jnp.asarray(base.astype(np.int32))
        t0 = time.perf_counter()
        rounds = 20
        for _ in range(rounds):
            pol.addto(q)
            pol.read_and_clear()
        us = (time.perf_counter() - t0) / rounds * 1e6
        rows.append((f"t6/lazy_ovf_{ratio}", round(us, 1),
                     f"fallback_resets={pol.stats.fallback_resets}"))
    return rows
