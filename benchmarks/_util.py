"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax

from repro import compat


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def host_mesh(model: int = 2):
    n = len(jax.devices())
    return compat.make_mesh((n // model, model), ("data", "model"))
