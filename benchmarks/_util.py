"""Shared benchmark helpers."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro import compat


def write_bench_json(name: str, config: dict, rows: list,
                     acceptance: dict | None = None) -> Path:
    """Machine-readable perf-trajectory export: ``BENCH_<name>.json``
    next to results.csv, holding the run's config, every metric row, and
    the acceptance verdicts — diffable across PRs (results.csv only
    appends). scripts/ci.sh asserts these files parse.

    ``rows`` are the benchmark's usual ``(metric, value, note)`` tuples.
    """
    payload = {
        "bench": name,
        "config": config,
        "rows": [{"metric": m, "value": v, "note": n} for m, v, n in rows],
        "acceptance": acceptance or {},
    }
    out = Path(__file__).resolve().parent / f"BENCH_{name}.json"
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return out


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def host_mesh(model: int = 2):
    n = len(jax.devices())
    return compat.make_mesh((n // model, model), ("data", "model"))
