"""Figure 13 analogue: chaining two 'switches' doubles usable INC memory.

Two SwitchMemory instances form a longer pipeline; the server agent places
keys on either (§6.6: 'the server agent decides which key to put on which
switch'). CHR should hold up to 2M distinct keys with two switches vs M
with one, degrading beyond.
"""
from __future__ import annotations

import numpy as np

from repro.core.inc_map import ServerAgent, SwitchMemory


class ChainedAgent:
    """Key-range split across two single-switch server agents."""

    def __init__(self, cap_each: int):
        self.a = ServerAgent(SwitchMemory(2, cap_each), 1, cap_each,
                             policy="fcfs")
        self.b = ServerAgent(SwitchMemory(2, cap_each), 1, cap_each,
                             policy="fcfs")

    def addto_batch(self, keys, vals):
        m = (keys % 2).astype(bool)
        if (~m).any():
            self.a.addto_batch(keys[~m], vals[~m])
        if m.any():
            self.b.addto_batch(keys[m], vals[m])

    @property
    def cache_hit_ratio(self):
        h = self.a.hits + self.b.hits
        t = h + self.a.misses + self.b.misses
        return h / t if t else 0.0


def run():
    rows = []
    cap = 2048                      # M = per-switch capacity
    rng = np.random.RandomState(11)
    for n_keys in (cap // 2, cap, 2 * cap, 5 * cap // 2):
        for label, agent in (("one_switch",
                              ServerAgent(SwitchMemory(2, cap), 1, cap,
                                          policy="fcfs")),
                             ("two_switch", ChainedAgent(cap))):
            for _ in range(20):
                keys = rng.randint(0, n_keys, 512).astype(np.uint32)
                agent.addto_batch(keys, np.ones(512, np.int64))
            rows.append((f"f13/{label}/keys_{n_keys}", 0,
                         f"chr={agent.cache_hit_ratio:.3f}"))
    return rows
